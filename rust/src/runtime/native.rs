//! Native CPU execution backend: pure-Rust forward/backward for the
//! model zoo, used when no AOT artifacts (or PJRT support) are present.
//!
//! The offline build cannot reach the `xla` registry crate, and a fresh
//! checkout has no compiled HLO artifacts — yet the coordinator, the
//! all-reduce trainer, and the quickstart example all need a real
//! gradient engine. This module implements the same mathematical
//! specification as `python/compile/kernels/ref.py` (Keras LSTM gate
//! order i,f,g,o with `unit_forget_bias`, tanh MLP, mean softmax
//! cross-entropy) so `mpi-learn` trains end-to-end with zero external
//! dependencies. Parameter flattening follows the manifest convention:
//! sorted parameter names, row-major tensors.
//!
//! Supported families: `mlp` (the quickstart model) and `lstm` (the
//! paper benchmark). `transformer` still requires the PJRT path.

use crate::runtime::artifact::ModelMeta;
use crate::runtime::executor::{GradOutput, RuntimeError};
use crate::tensor::ParamSet;

/// A natively-executable model variant.
pub(crate) enum NativeModel {
    Mlp(MlpNet),
    Lstm(LstmNet),
}

/// Tanh MLP over flattened input: dims[0] -> … -> dims.last().
pub(crate) struct MlpNet {
    batch: usize,
    /// Layer widths including input and output: [d_in, h0, …, classes].
    dims: Vec<usize>,
}

/// Single-layer LSTM + linear head (the paper's LSTM(20) benchmark).
pub(crate) struct LstmNet {
    batch: usize,
    seq_len: usize,
    features: usize,
    hidden: usize,
    classes: usize,
}

/// Keras `unit_forget_bias=True` analogue (see kernels/ref.py).
const FORGET_BIAS: f32 = 1.0;

// ---------------------------------------------------------------------------
// dense math helpers (row-major)
// ---------------------------------------------------------------------------

/// C[rows, cols] += A[rows, inner] @ B[inner, cols]
fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32], rows: usize,
              inner: usize, cols: usize) {
    debug_assert_eq!(a.len(), rows * inner);
    debug_assert_eq!(b.len(), inner * cols);
    debug_assert_eq!(c.len(), rows * cols);
    for i in 0..rows {
        let arow = &a[i * inner..(i + 1) * inner];
        let crow = &mut c[i * cols..(i + 1) * cols];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &b[p * cols..(p + 1) * cols];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[rows, cols] += A[inner, rows]^T @ B[inner, cols]
fn matmul_tn_acc(a: &[f32], b: &[f32], c: &mut [f32], rows: usize,
                 inner: usize, cols: usize) {
    debug_assert_eq!(a.len(), inner * rows);
    debug_assert_eq!(b.len(), inner * cols);
    debug_assert_eq!(c.len(), rows * cols);
    for p in 0..inner {
        let arow = &a[p * rows..(p + 1) * rows];
        let brow = &b[p * cols..(p + 1) * cols];
        for (i, &av) in arow.iter().enumerate() {
            let crow = &mut c[i * cols..(i + 1) * cols];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// C[rows, cols] += A[rows, inner] @ B[cols, inner]^T
fn matmul_nt_acc(a: &[f32], b: &[f32], c: &mut [f32], rows: usize,
                 inner: usize, cols: usize) {
    debug_assert_eq!(a.len(), rows * inner);
    debug_assert_eq!(b.len(), cols * inner);
    debug_assert_eq!(c.len(), rows * cols);
    for i in 0..rows {
        let arow = &a[i * inner..(i + 1) * inner];
        for j in 0..cols {
            let brow = &b[j * inner..(j + 1) * inner];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            c[i * cols + j] += acc;
        }
    }
}

fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

/// Zeroed flat-gradient buffer with two spare capacity slots: the
/// all-reduce trainer piggybacks the batch loss and the early-stop flag
/// with `push`es, which must not reallocate (and memcpy) the whole
/// gradient every round.
pub(crate) fn grad_buffer(n: usize) -> Vec<f32> {
    let mut buf = Vec::with_capacity(n + 2);
    buf.resize(n, 0.0);
    buf
}

/// Mean softmax cross-entropy over `[batch, classes]` logits, plus the
/// gradient d(loss)/d(logits) (already scaled by 1/batch).
fn softmax_xent_grad(logits: &[f32], y: &[i32], batch: usize,
                     classes: usize) -> (f32, Vec<f32>) {
    let mut loss = 0.0f64;
    let mut dz = vec![0.0f32; batch * classes];
    let inv_b = 1.0 / batch as f32;
    for row in 0..batch {
        let z = &logits[row * classes..(row + 1) * classes];
        let zmax = z.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &v in z {
            sum += (v - zmax).exp();
        }
        let label = y[row] as usize;
        loss += (sum.ln() - (z[label] - zmax)) as f64;
        let d = &mut dz[row * classes..(row + 1) * classes];
        for (j, &v) in z.iter().enumerate() {
            let p = (v - zmax).exp() / sum;
            d[j] = (p - if j == label { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    ((loss / batch as f64) as f32, dz)
}

fn argmax_correct(logits: &[f32], y: &[i32], batch: usize,
                  classes: usize) -> f32 {
    let mut correct = 0usize;
    for row in 0..batch {
        let z = &logits[row * classes..(row + 1) * classes];
        let mut best = 0usize;
        for (j, &v) in z.iter().enumerate() {
            if v > z[best] {
                best = j;
            }
        }
        if best == y[row] as usize {
            correct += 1;
        }
    }
    correct as f32
}

// ---------------------------------------------------------------------------
// model construction
// ---------------------------------------------------------------------------

impl NativeModel {
    /// Build from a manifest entry, validating that the parameter table
    /// matches what this backend can execute.
    pub(crate) fn from_meta(meta: &ModelMeta)
        -> Result<NativeModel, RuntimeError> {
        match meta.model.as_str() {
            "mlp" => MlpNet::from_meta(meta).map(NativeModel::Mlp),
            "lstm" => LstmNet::from_meta(meta).map(NativeModel::Lstm),
            other => Err(RuntimeError::Unsupported(format!(
                "model family '{other}' needs the PJRT backend \
                 (native backend supports mlp and lstm)"
            ))),
        }
    }

    pub(crate) fn grad_step(&self, params: &ParamSet, x: &[f32],
                            y: &[i32]) -> Result<GradOutput, RuntimeError> {
        match self {
            NativeModel::Mlp(m) => Ok(m.grad(params, x, y)),
            NativeModel::Lstm(m) => Ok(m.grad(params, x, y)),
        }
    }

    pub(crate) fn eval_step(&self, params: &ParamSet, x: &[f32],
                            y: &[i32]) -> Result<(f32, f32), RuntimeError> {
        let logits = self.logits(params, x);
        let (batch, classes) = self.out_shape();
        let (loss, _) = softmax_xent_grad(&logits, y, batch, classes);
        Ok((loss, argmax_correct(&logits, y, batch, classes)))
    }

    pub(crate) fn predict(&self, params: &ParamSet, x: &[f32])
        -> Result<Vec<f32>, RuntimeError> {
        Ok(self.logits(params, x))
    }

    fn logits(&self, params: &ParamSet, x: &[f32]) -> Vec<f32> {
        match self {
            NativeModel::Mlp(m) => m.forward(params, x).pop().unwrap(),
            NativeModel::Lstm(m) => m.forward(params, x).logits,
        }
    }

    fn out_shape(&self) -> (usize, usize) {
        match self {
            NativeModel::Mlp(m) => (m.batch, *m.dims.last().unwrap()),
            NativeModel::Lstm(m) => (m.batch, m.classes),
        }
    }
}

/// Synthesize the manifest entry for a natively-supported variant key
/// (`mlp_b100`, `lstm_b10`, …) using the quickstart/paper architecture
/// constants from `python/compile/model.py`. Returns `None` for keys the
/// native backend cannot serve.
pub(crate) fn meta_for_key(key: &str) -> Option<ModelMeta> {
    let (family, batch_s) = key.rsplit_once("_b")?;
    let batch: usize = batch_s.parse().ok()?;
    if batch == 0 {
        return None;
    }
    let (seq_len, features, classes, hidden) = (30usize, 16usize, 3usize,
                                                20usize);
    let params: Vec<(String, Vec<usize>)> = match family {
        "mlp" => {
            let dims = [seq_len * features, 64, 32, classes];
            let mut p = Vec::new();
            for li in 0..dims.len() - 1 {
                p.push((format!("fc{li}_b"), vec![dims[li + 1]]));
                p.push((format!("fc{li}_w"), vec![dims[li], dims[li + 1]]));
            }
            p
        }
        "lstm" => vec![
            ("lstm_b".into(), vec![4 * hidden]),
            ("lstm_wh".into(), vec![hidden, 4 * hidden]),
            ("lstm_wx".into(), vec![features, 4 * hidden]),
            ("out_b".into(), vec![classes]),
            ("out_w".into(), vec![hidden, classes]),
        ],
        _ => return None,
    };
    let param_count = params
        .iter()
        .map(|(_, s)| s.iter().product::<usize>())
        .sum();
    Some(ModelMeta {
        key: key.to_string(),
        model: family.to_string(),
        batch,
        seq_len,
        features,
        classes,
        hidden,
        params,
        param_count,
        grad_file: std::path::PathBuf::from("native"),
        eval_file: std::path::PathBuf::from("native"),
        predict_file: std::path::PathBuf::from("native"),
    })
}

// ---------------------------------------------------------------------------
// MLP
// ---------------------------------------------------------------------------

impl MlpNet {
    fn from_meta(meta: &ModelMeta) -> Result<MlpNet, RuntimeError> {
        let bad = |msg: String| RuntimeError::Unsupported(msg);
        if meta.params.len() < 2 || meta.params.len() % 2 != 0 {
            return Err(bad(format!(
                "mlp '{}': expected fc{{i}}_b/fc{{i}}_w parameter pairs",
                meta.key
            )));
        }
        let n_layers = meta.params.len() / 2;
        let mut dims = vec![meta.seq_len * meta.features];
        for li in 0..n_layers {
            let (bname, bshape) = &meta.params[2 * li];
            let (wname, wshape) = &meta.params[2 * li + 1];
            if bname != &format!("fc{li}_b") || wname != &format!("fc{li}_w")
                || wshape.len() != 2 || bshape.len() != 1
                || wshape[0] != dims[li] || wshape[1] != bshape[0]
            {
                return Err(bad(format!(
                    "mlp '{}': unexpected parameter table at layer {li}",
                    meta.key
                )));
            }
            dims.push(wshape[1]);
        }
        if *dims.last().unwrap() != meta.classes {
            return Err(bad(format!(
                "mlp '{}': output width != classes", meta.key
            )));
        }
        Ok(MlpNet { batch: meta.batch, dims })
    }

    /// Forward pass; returns activations per layer (acts[0] = flat x,
    /// acts.last() = logits; hidden activations are post-tanh).
    fn forward(&self, params: &ParamSet, x: &[f32]) -> Vec<Vec<f32>> {
        let b = self.batch;
        let n_layers = self.dims.len() - 1;
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers + 1);
        acts.push(x.to_vec());
        for li in 0..n_layers {
            let bias = params.slice(2 * li);
            let w = params.slice(2 * li + 1);
            let (m, n) = (self.dims[li], self.dims[li + 1]);
            let mut z = vec![0.0f32; b * n];
            for row in 0..b {
                z[row * n..(row + 1) * n].copy_from_slice(bias);
            }
            matmul_acc(&acts[li], w, &mut z, b, m, n);
            if li < n_layers - 1 {
                for v in &mut z {
                    *v = v.tanh();
                }
            }
            acts.push(z);
        }
        acts
    }

    fn grad(&self, params: &ParamSet, x: &[f32], y: &[i32]) -> GradOutput {
        let b = self.batch;
        let n_layers = self.dims.len() - 1;
        let classes = *self.dims.last().unwrap();
        let acts = self.forward(params, x);
        let (loss, mut dz) = softmax_xent_grad(acts.last().unwrap(), y, b,
                                               classes);
        let mut grads = grad_buffer(params.num_params());
        let views = params.views();
        for li in (0..n_layers).rev() {
            let (m, n) = (self.dims[li], self.dims[li + 1]);
            let bv = &views[2 * li];
            let wv = &views[2 * li + 1];
            matmul_tn_acc(&acts[li], &dz,
                          &mut grads[wv.offset..wv.offset + wv.len],
                          m, b, n);
            let db = &mut grads[bv.offset..bv.offset + bv.len];
            for row in 0..b {
                for (j, dbj) in db.iter_mut().enumerate() {
                    *dbj += dz[row * n + j];
                }
            }
            if li > 0 {
                let w = params.slice(2 * li + 1);
                let mut dh = vec![0.0f32; b * m];
                matmul_nt_acc(&dz, w, &mut dh, b, n, m);
                for (d, &h) in dh.iter_mut().zip(&acts[li]) {
                    *d *= 1.0 - h * h;
                }
                dz = dh;
            }
        }
        GradOutput { loss, grads }
    }
}

// ---------------------------------------------------------------------------
// LSTM
// ---------------------------------------------------------------------------

/// Forward-pass state kept for backprop-through-time.
struct LstmForward {
    logits: Vec<f32>,
    /// h[t] for t = 0..=T (h[0] is the zero initial state), each [B, H].
    hs: Vec<Vec<f32>>,
    /// c[t] for t = 0..=T, each [B, H].
    cs: Vec<Vec<f32>>,
    /// Per-step activated gates (i, f, g, o), each [B, H].
    gates: Vec<[Vec<f32>; 4]>,
}

impl LstmNet {
    fn from_meta(meta: &ModelMeta) -> Result<LstmNet, RuntimeError> {
        let h = meta.hidden;
        let expect: Vec<(String, Vec<usize>)> = vec![
            ("lstm_b".into(), vec![4 * h]),
            ("lstm_wh".into(), vec![h, 4 * h]),
            ("lstm_wx".into(), vec![meta.features, 4 * h]),
            ("out_b".into(), vec![meta.classes]),
            ("out_w".into(), vec![h, meta.classes]),
        ];
        if meta.params != expect {
            return Err(RuntimeError::Unsupported(format!(
                "lstm '{}': parameter table does not match the \
                 single-layer LSTM this backend implements",
                meta.key
            )));
        }
        Ok(LstmNet {
            batch: meta.batch,
            seq_len: meta.seq_len,
            features: meta.features,
            hidden: h,
            classes: meta.classes,
        })
    }

    /// Copy time-step `t` of `[B, T, F]` input into a `[B, F]` buffer.
    fn step_input(&self, x: &[f32], t: usize, out: &mut [f32]) {
        let (tt, ff) = (self.seq_len, self.features);
        for bi in 0..self.batch {
            let src = bi * tt * ff + t * ff;
            out[bi * ff..(bi + 1) * ff]
                .copy_from_slice(&x[src..src + ff]);
        }
    }

    fn forward(&self, params: &ParamSet, x: &[f32]) -> LstmForward {
        let (b, h, ff) = (self.batch, self.hidden, self.features);
        let bias = params.slice(0);
        let wh = params.slice(1);
        let wx = params.slice(2);
        let out_b = params.slice(3);
        let out_w = params.slice(4);

        let mut hs = vec![vec![0.0f32; b * h]];
        let mut cs = vec![vec![0.0f32; b * h]];
        let mut gates = Vec::with_capacity(self.seq_len);
        let mut xt = vec![0.0f32; b * ff];
        for t in 0..self.seq_len {
            self.step_input(x, t, &mut xt);
            let mut z = vec![0.0f32; b * 4 * h];
            for row in 0..b {
                z[row * 4 * h..(row + 1) * 4 * h].copy_from_slice(bias);
            }
            matmul_acc(&xt, wx, &mut z, b, ff, 4 * h);
            matmul_acc(&hs[t], wh, &mut z, b, h, 4 * h);

            let mut gi = vec![0.0f32; b * h];
            let mut gf = vec![0.0f32; b * h];
            let mut gg = vec![0.0f32; b * h];
            let mut go = vec![0.0f32; b * h];
            let mut c_new = vec![0.0f32; b * h];
            let mut h_new = vec![0.0f32; b * h];
            let c_prev = &cs[t];
            for row in 0..b {
                for j in 0..h {
                    let zrow = &z[row * 4 * h..(row + 1) * 4 * h];
                    let k = row * h + j;
                    let i = sigmoid(zrow[j]);
                    let f = sigmoid(zrow[h + j] + FORGET_BIAS);
                    let g = zrow[2 * h + j].tanh();
                    let o = sigmoid(zrow[3 * h + j]);
                    let c = f * c_prev[k] + i * g;
                    gi[k] = i;
                    gf[k] = f;
                    gg[k] = g;
                    go[k] = o;
                    c_new[k] = c;
                    h_new[k] = o * c.tanh();
                }
            }
            gates.push([gi, gf, gg, go]);
            hs.push(h_new);
            cs.push(c_new);
        }

        let mut logits = vec![0.0f32; b * self.classes];
        for row in 0..b {
            logits[row * self.classes..(row + 1) * self.classes]
                .copy_from_slice(out_b);
        }
        matmul_acc(hs.last().unwrap(), out_w, &mut logits, b, h,
                   self.classes);
        LstmForward { logits, hs, cs, gates }
    }

    fn grad(&self, params: &ParamSet, x: &[f32], y: &[i32]) -> GradOutput {
        let (b, h, ff, c_out) = (self.batch, self.hidden, self.features,
                                 self.classes);
        let fwd = self.forward(params, x);
        let (loss, dlogits) = softmax_xent_grad(&fwd.logits, y, b, c_out);

        let views = params.views();
        let mut grads = grad_buffer(params.num_params());
        let wh = params.slice(1);
        let out_w = params.slice(4);

        // head: out_w [H, C], out_b [C]
        {
            let wv = &views[4];
            matmul_tn_acc(fwd.hs.last().unwrap(), &dlogits,
                          &mut grads[wv.offset..wv.offset + wv.len],
                          h, b, c_out);
            let bv = &views[3];
            let db = &mut grads[bv.offset..bv.offset + bv.len];
            for row in 0..b {
                for (j, dbj) in db.iter_mut().enumerate() {
                    *dbj += dlogits[row * c_out + j];
                }
            }
        }

        // dh flowing into the last hidden state
        let mut dh = vec![0.0f32; b * h];
        matmul_nt_acc(&dlogits, out_w, &mut dh, b, c_out, h);
        let mut dc = vec![0.0f32; b * h];

        let mut xt = vec![0.0f32; b * ff];
        let mut dz = vec![0.0f32; b * 4 * h];
        for t in (0..self.seq_len).rev() {
            let [gi, gf, gg, go] = &fwd.gates[t];
            let c_new = &fwd.cs[t + 1];
            let c_prev = &fwd.cs[t];
            for k in 0..b * h {
                let tc = c_new[k].tanh();
                let dck = dc[k] + dh[k] * go[k] * (1.0 - tc * tc);
                let dok = dh[k] * tc;
                let row = k / h;
                let j = k % h;
                let zrow = &mut dz[row * 4 * h..(row + 1) * 4 * h];
                zrow[j] = dck * gg[k] * gi[k] * (1.0 - gi[k]);
                zrow[h + j] = dck * c_prev[k] * gf[k] * (1.0 - gf[k]);
                zrow[2 * h + j] = dck * gi[k] * (1.0 - gg[k] * gg[k]);
                zrow[3 * h + j] = dok * go[k] * (1.0 - go[k]);
                // carry to c_{t-1}; dh_{t-1} is recomputed below
                dc[k] = dck * gf[k];
            }
            self.step_input(x, t, &mut xt);
            // lstm_wx [F, 4H] at view 2, lstm_wh [H, 4H] at view 1,
            // lstm_b [4H] at view 0
            {
                let wv = &views[2];
                matmul_tn_acc(&xt, &dz,
                              &mut grads[wv.offset..wv.offset + wv.len],
                              ff, b, 4 * h);
            }
            {
                let wv = &views[1];
                matmul_tn_acc(&fwd.hs[t], &dz,
                              &mut grads[wv.offset..wv.offset + wv.len],
                              h, b, 4 * h);
            }
            {
                let bv = &views[0];
                let db = &mut grads[bv.offset..bv.offset + bv.len];
                for row in 0..b {
                    for (j, dbj) in db.iter_mut().enumerate() {
                        *dbj += dz[row * 4 * h + j];
                    }
                }
            }
            for v in dh.iter_mut() {
                *v = 0.0;
            }
            matmul_nt_acc(&dz, wh, &mut dh, b, 4 * h, h);
        }
        GradOutput { loss, grads }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fd_check(meta: &ModelMeta, model: &NativeModel, probes: usize) {
        // Directional finite difference in f32: the whole-gradient
        // projection is much more stable than per-coordinate probes.
        let mut rng = Rng::new(42);
        let params = ParamSet::glorot_init(&meta.params, &mut rng);
        let x: Vec<f32> = (0..meta.batch * meta.seq_len * meta.features)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let y: Vec<i32> = (0..meta.batch)
            .map(|_| rng.usize_below(meta.classes) as i32)
            .collect();
        let out = model.grad_step(&params, &x, &y).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        assert_eq!(out.grads.len(), meta.param_count);
        for _ in 0..probes {
            let dir: Vec<f32> = (0..params.num_params())
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let eps = 1e-3f32;
            let mut plus = params.clone();
            plus.axpy(eps, &dir);
            let mut minus = params.clone();
            minus.axpy(-eps, &dir);
            let (lp, _) = model.eval_step(&plus, &x, &y).unwrap();
            let (lm, _) = model.eval_step(&minus, &x, &y).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            let analytic: f32 =
                out.grads.iter().zip(&dir).map(|(g, d)| g * d).sum();
            let denom = fd.abs().max(analytic.abs()).max(1e-3);
            assert!(
                (fd - analytic).abs() / denom < 0.05,
                "fd={fd} analytic={analytic}"
            );
        }
    }

    #[test]
    fn meta_for_key_matches_known_param_counts() {
        let mlp = meta_for_key("mlp_b100").unwrap();
        assert_eq!(mlp.param_count, 32_963);
        assert_eq!(mlp.batch, 100);
        let lstm = meta_for_key("lstm_b10").unwrap();
        assert_eq!(lstm.param_count, 3_023);
        assert!(meta_for_key("transformer_b16").is_none());
        assert!(meta_for_key("garbage").is_none());
        assert!(meta_for_key("mlp_b0").is_none());
    }

    #[test]
    fn mlp_gradient_matches_finite_difference() {
        let meta = meta_for_key("mlp_b10").unwrap();
        let model = NativeModel::from_meta(&meta).unwrap();
        fd_check(&meta, &model, 3);
    }

    #[test]
    fn lstm_gradient_matches_finite_difference() {
        let meta = meta_for_key("lstm_b10").unwrap();
        let model = NativeModel::from_meta(&meta).unwrap();
        fd_check(&meta, &model, 3);
    }

    #[test]
    fn eval_counts_correct_predictions() {
        let meta = meta_for_key("mlp_b10").unwrap();
        let model = NativeModel::from_meta(&meta).unwrap();
        let mut rng = Rng::new(1);
        let params = ParamSet::glorot_init(&meta.params, &mut rng);
        let x = vec![0.1f32; meta.batch * meta.seq_len * meta.features];
        let y = vec![0i32; meta.batch];
        let (loss, ncorrect) = model.eval_step(&params, &x, &y).unwrap();
        assert!(loss.is_finite());
        assert!((0.0..=meta.batch as f32).contains(&ncorrect));
        let logits = model.predict(&params, &x).unwrap();
        assert_eq!(logits.len(), meta.batch * meta.classes);
    }

    #[test]
    fn gradient_descent_reduces_loss() {
        // A few plain-SGD steps on one fixed batch must reduce the loss
        // for both families — end-to-end backprop sanity.
        for key in ["mlp_b10", "lstm_b10"] {
            let meta = meta_for_key(key).unwrap();
            let model = NativeModel::from_meta(&meta).unwrap();
            let mut rng = Rng::new(7);
            let mut params = ParamSet::glorot_init(&meta.params, &mut rng);
            let x: Vec<f32> = (0..meta.batch * meta.seq_len * meta.features)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let y: Vec<i32> = (0..meta.batch)
                .map(|_| rng.usize_below(meta.classes) as i32)
                .collect();
            let first = model.grad_step(&params, &x, &y).unwrap();
            let mut last = first.loss;
            for _ in 0..50 {
                let out = model.grad_step(&params, &x, &y).unwrap();
                params.axpy(-0.1, &out.grads);
                last = out.loss;
            }
            assert!(
                last < first.loss * 0.6,
                "{key}: loss {} -> {last} did not drop",
                first.loss
            );
        }
    }
}
