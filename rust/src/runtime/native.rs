//! Native CPU execution backend: pure-Rust forward/backward for the
//! model zoo, used when no AOT artifacts (or PJRT support) are present.
//!
//! The offline build cannot reach the `xla` registry crate, and a fresh
//! checkout has no compiled HLO artifacts — yet the coordinator, the
//! all-reduce trainer, and the quickstart example all need a real
//! gradient engine. This module implements the same mathematical
//! specification as `python/compile/kernels/ref.py` (Keras LSTM gate
//! order i,f,g,o with `unit_forget_bias`, tanh MLP, mean softmax
//! cross-entropy) so `mpi-learn` trains end-to-end with zero external
//! dependencies. Parameter flattening follows the manifest convention:
//! sorted parameter names, row-major tensors.
//!
//! Models execute as an explicit **layer DAG** ([`LayerDag`]): each
//! node implements [`Layer`] (`forward` + a two-half `backward`), owns
//! one contiguous slice of the flat parameter vector
//! ([`crate::tensor::ParamSet::layer_ranges`]), and the backward sweep
//! runs nodes in reverse topological order, emitting a
//! [`BucketReady`] event through a [`GradSink`] the moment a node's
//! gradient slice is final — before upstream nodes compute. That event
//! stream is what drives the bucketed, compute-overlapped all-reduce
//! (see DESIGN.md §Layer DAG & bucketed overlap). Scratch buffers
//! (activations, tapes, per-step temporaries) come from a per-call
//! [`Arena`] pool so steady-state training rounds stop reallocating.
//!
//! Supported families: `mlp` (the quickstart model) and `lstm` (the
//! paper benchmark). `transformer` still requires the PJRT path.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::artifact::ModelMeta;
use crate::runtime::executor::{BucketReady, GradOutput, GradSink,
                               RuntimeError};
use crate::runtime::kernels;
use crate::tensor::ParamSet;
use crate::util::threadpool::{SharedMut, ThreadPool};

/// A natively-executable model: the layer DAG plus the scratch-arena
/// pool shared by every caller of this (Arc-shared) executable.
pub(crate) struct NativeModel {
    dag: LayerDag,
    /// Retired scratch buffers, one arena per concurrent caller
    /// (popped for the duration of a step, pushed back after).
    arenas: Mutex<Vec<Arena>>,
    /// When false, every step runs on a fresh arena and nothing is
    /// pooled — the microbench baseline.
    reuse_scratch: AtomicBool,
    /// Compute pool the kernels fan out over. Constructed solo (one
    /// thread, zero helpers — the exact legacy scalar path) and
    /// resized once by [`NativeModel::set_threads`]; results are
    /// bitwise-identical at any size (see `runtime/kernels.rs`).
    pool: Mutex<Arc<ThreadPool>>,
}

/// Per-step execution context threaded through the layer DAG: the
/// scratch arena plus the compute pool the kernels run on.
pub(crate) struct Ctx<'a> {
    pub(crate) arena: &'a mut Arena,
    pub(crate) pool: &'a ThreadPool,
}

/// Tanh MLP over flattened input: dims[0] -> … -> dims.last().
pub(crate) struct MlpNet {
    batch: usize,
    /// Layer widths including input and output: [d_in, h0, …, classes].
    dims: Vec<usize>,
}

/// Single-layer LSTM + linear head (the paper's LSTM(20) benchmark).
pub(crate) struct LstmNet {
    batch: usize,
    seq_len: usize,
    features: usize,
    hidden: usize,
    classes: usize,
}

/// Keras `unit_forget_bias=True` analogue (see kernels/ref.py).
const FORGET_BIAS: f32 = 1.0;

// The monolithic test oracles below spell the matmuls unqualified —
// they must stay on the scalar references so the monolith-vs-DAG
// bitwise test pins the pooled kernels to the scalar order end to end.
#[cfg(test)]
use crate::runtime::kernels::scalar::{matmul_acc, matmul_nt_acc,
                                      matmul_tn_acc};

// ---------------------------------------------------------------------------
// dense math helpers (row-major)
// ---------------------------------------------------------------------------
//
// The accumulating matmuls (`matmul_acc` / `matmul_tn_acc` /
// `matmul_nt_acc`) live in `runtime/kernels.rs` now: lane-chunked,
// pool-parallel, and property-tested to be bitwise-identical to the
// scalar references (`kernels::scalar`) at any thread count. The
// monolithic test oracles below still call the scalar references, so
// the monolith-vs-DAG bitwise tests also pin kernels-vs-scalar
// end to end.

fn sigmoid(v: f32) -> f32 {
    1.0 / (1.0 + (-v).exp())
}

/// Zeroed flat-gradient buffer with two spare capacity slots: the
/// all-reduce trainer piggybacks the batch loss and the early-stop flag
/// with `push`es, which must not reallocate (and memcpy) the whole
/// gradient every round.
pub(crate) fn grad_buffer(n: usize) -> Vec<f32> {
    let mut buf = Vec::with_capacity(n + 2);
    buf.resize(n, 0.0);
    buf
}

/// Mean softmax cross-entropy over `[batch, classes]` logits, plus the
/// gradient d(loss)/d(logits) (already scaled by 1/batch).
fn softmax_xent_grad(logits: &[f32], y: &[i32], batch: usize,
                     classes: usize) -> (f32, Vec<f32>) {
    let mut loss = 0.0f64;
    let mut dz = vec![0.0f32; batch * classes];
    let inv_b = 1.0 / batch as f32;
    for row in 0..batch {
        let z = &logits[row * classes..(row + 1) * classes];
        let zmax = z.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &v in z {
            sum += (v - zmax).exp();
        }
        let label = y[row] as usize;
        loss += (sum.ln() - (z[label] - zmax)) as f64;
        let d = &mut dz[row * classes..(row + 1) * classes];
        for (j, &v) in z.iter().enumerate() {
            let p = (v - zmax).exp() / sum;
            d[j] = (p - if j == label { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    ((loss / batch as f64) as f32, dz)
}

fn argmax_correct(logits: &[f32], y: &[i32], batch: usize,
                  classes: usize) -> f32 {
    let mut correct = 0usize;
    for row in 0..batch {
        let z = &logits[row * classes..(row + 1) * classes];
        let mut best = 0usize;
        for (j, &v) in z.iter().enumerate() {
            if v > z[best] {
                best = j;
            }
        }
        if best == y[row] as usize {
            correct += 1;
        }
    }
    correct as f32
}

/// Copy time-step `t` of `[B, T, F]` input into a `[B, F]` buffer.
fn step_input(x: &[f32], t: usize, batch: usize, seq_len: usize,
              features: usize, out: &mut [f32]) {
    for bi in 0..batch {
        let src = bi * seq_len * features + t * features;
        out[bi * features..(bi + 1) * features]
            .copy_from_slice(&x[src..src + features]);
    }
}

// ---------------------------------------------------------------------------
// scratch arena
// ---------------------------------------------------------------------------

/// Recycled scratch allocations for one in-flight step. `take_zeroed`
/// hands out a zeroed buffer, reusing a retired allocation when one is
/// big enough; `put` retires a buffer for later reuse. Buffers carry no
/// identity — any retired allocation with enough capacity serves any
/// request — so the values a step computes are independent of what the
/// arena previously held (zeroing guarantees it).
pub(crate) struct Arena {
    free: Vec<Vec<f32>>,
}

impl Arena {
    fn new() -> Arena {
        Arena { free: Vec::new() }
    }

    fn take_zeroed(&mut self, n: usize) -> Vec<f32> {
        match self.free.iter().position(|v| v.capacity() >= n) {
            Some(i) => {
                let mut v = self.free.swap_remove(i);
                v.clear();
                v.resize(n, 0.0);
                v
            }
            None => vec![0.0f32; n],
        }
    }

    fn put(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.free.push(v);
        }
    }
}

/// Backward state a node's `forward` leaves for its backward half,
/// beyond the input activation itself (which the DAG retains).
pub(crate) enum Tape {
    /// Dense nodes need only their input activation.
    None,
    /// LSTM recurrence state: h[t]/c[t] and the activated gates
    /// (i, f, g, o) per step. `hs[t]` is the state *entering* step t
    /// (the final state is the node's output activation, not kept
    /// here); `cs` spans 0..=T.
    Lstm {
        hs: Vec<Vec<f32>>,
        cs: Vec<Vec<f32>>,
        gates: Vec<[Vec<f32>; 4]>,
    },
}

impl Tape {
    fn recycle(self, arena: &mut Arena) {
        match self {
            Tape::None => {}
            Tape::Lstm { hs, cs, gates } => {
                for v in hs {
                    arena.put(v);
                }
                for v in cs {
                    arena.put(v);
                }
                for step in gates {
                    for v in step {
                        arena.put(v);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// layer DAG
// ---------------------------------------------------------------------------

/// One node of the model DAG. Backward is split in two halves so the
/// DAG can emit [`BucketReady`] between them: once `accumulate_grads`
/// returns, this node's slice of the flat gradient is FINAL and may hit
/// the wire while `input_grad` (and every upstream node) still
/// computes.
pub(crate) trait Layer {
    /// Node name (for diagnostics; matches the
    /// [`ParamSet::layer_ranges`] prefix).
    fn name(&self) -> &str;

    /// Contiguous range of the flat parameter/gradient vector this
    /// node owns.
    fn param_range(&self) -> Range<usize>;

    /// Consume the upstream activation (`input`; the raw model input
    /// for the first node) and produce this node's output activation
    /// plus its backward tape.
    fn forward(&self, params: &ParamSet, input: &[f32],
               ctx: &mut Ctx) -> (Vec<f32>, Tape);

    /// First backward half: accumulate d(loss)/d(own params) into
    /// `grads[param_range]` from the downstream gradient `dz`.
    fn accumulate_grads(&self, params: &ParamSet, input: &[f32],
                        tape: &Tape, dz: &[f32], grads: &mut [f32],
                        ctx: &mut Ctx);

    /// Second backward half: the gradient flowing to the upstream node
    /// (`None` for a node with no trainable upstream), consuming `dz`.
    fn input_grad(&self, params: &ParamSet, input: &[f32], tape: &Tape,
                  dz: Vec<f32>, ctx: &mut Ctx) -> Option<Vec<f32>>;

    /// Full backward: both halves, no emission point. The DAG calls
    /// the halves separately so the bucket launch can sit in between.
    fn backward(&self, params: &ParamSet, input: &[f32], tape: &Tape,
                dz: Vec<f32>, grads: &mut [f32], ctx: &mut Ctx)
        -> Option<Vec<f32>> {
        self.accumulate_grads(params, input, tape, &dz, grads, ctx);
        self.input_grad(params, input, tape, dz, ctx)
    }
}

/// The model as an explicit chain of [`Layer`] nodes (a linear DAG:
/// node i feeds node i+1). Forward runs in topological order; backward
/// in reverse, emitting [`BucketReady`] per node.
pub(crate) struct LayerDag {
    nodes: Vec<Box<dyn Layer + Send + Sync>>,
    batch: usize,
    classes: usize,
}

impl LayerDag {
    /// Forward chain; returns per-node output activations and tapes
    /// (acts.last() = logits).
    fn forward(&self, params: &ParamSet, x: &[f32], ctx: &mut Ctx)
        -> (Vec<Vec<f32>>, Vec<Tape>) {
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.nodes.len());
        let mut tapes: Vec<Tape> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            let input: &[f32] = match acts.last() {
                Some(a) => a,
                None => x,
            };
            let (out, tape) = node.forward(params, input, ctx);
            acts.push(out);
            tapes.push(tape);
        }
        (acts, tapes)
    }

    /// Loss + flat gradient, emitting one [`BucketReady`] per node in
    /// reverse topological order, each fired the moment that node's
    /// gradient slice is final.
    fn grad(&self, params: &ParamSet, x: &[f32], y: &[i32],
            ctx: &mut Ctx, sink: &mut dyn GradSink) -> GradOutput {
        let (acts, tapes) = self.forward(params, x, ctx);
        let (loss, mut dz) = softmax_xent_grad(
            acts.last().unwrap(), y, self.batch, self.classes);
        let mut grads = grad_buffer(params.num_params());
        for i in (0..self.nodes.len()).rev() {
            let node = &self.nodes[i];
            let input: &[f32] = if i == 0 { x } else { &acts[i - 1] };
            node.accumulate_grads(params, input, &tapes[i], &dz,
                                  &mut grads, ctx);
            sink.bucket_ready(
                BucketReady { layer: i, param_range: node.param_range() },
                &grads);
            match node.input_grad(params, input, &tapes[i],
                                  std::mem::take(&mut dz), ctx) {
                Some(d) => dz = d,
                None => break,
            }
        }
        ctx.arena.put(dz);
        for tape in tapes {
            tape.recycle(ctx.arena);
        }
        for act in acts {
            ctx.arena.put(act);
        }
        GradOutput { loss, grads }
    }

    /// Forward-only logits (caller owns the returned buffer; interior
    /// activations and tapes are recycled).
    fn logits(&self, params: &ParamSet, x: &[f32], ctx: &mut Ctx)
        -> Vec<f32> {
        let (mut acts, tapes) = self.forward(params, x, ctx);
        let out = acts.pop().unwrap();
        for tape in tapes {
            tape.recycle(ctx.arena);
        }
        for act in acts {
            ctx.arena.put(act);
        }
        out
    }
}

/// Fully-connected node: `z = input @ w + b`, optional tanh. Serves
/// both the MLP's `fc{i}` layers and the LSTM's linear head.
struct DenseLayer {
    name: String,
    batch: usize,
    in_dim: usize,
    out_dim: usize,
    /// ParamSet view indices (declaration order: bias, then weight).
    bias_view: usize,
    w_view: usize,
    /// Flat range covering bias + weight (contiguous by layout).
    range: Range<usize>,
    /// Apply tanh to the output (hidden MLP layers; logits layers are
    /// linear).
    tanh: bool,
    /// The upstream node applied tanh, so the emitted input gradient
    /// must include tanh' — computed here, consumer side, preserving
    /// the monolithic op order bit for bit.
    input_tanh: bool,
    /// No trainable upstream: skip the input-gradient matmul entirely.
    first: bool,
}

impl Layer for DenseLayer {
    fn name(&self) -> &str {
        &self.name
    }

    fn param_range(&self) -> Range<usize> {
        self.range.clone()
    }

    fn forward(&self, params: &ParamSet, input: &[f32],
               ctx: &mut Ctx) -> (Vec<f32>, Tape) {
        let (b, m, n) = (self.batch, self.in_dim, self.out_dim);
        let bias = params.slice(self.bias_view);
        let w = params.slice(self.w_view);
        let mut z = ctx.arena.take_zeroed(b * n);
        for row in 0..b {
            z[row * n..(row + 1) * n].copy_from_slice(bias);
        }
        kernels::matmul_acc(ctx.pool, input, w, &mut z, b, m, n);
        if self.tanh {
            // elementwise, so pooled blocks keep per-element op order
            let zv = SharedMut::new(&mut z);
            kernels::par_blocks(ctx.pool, b * n, |r| {
                let zs = unsafe { zv.range(r) };
                for v in zs {
                    *v = v.tanh();
                }
            });
        }
        (z, Tape::None)
    }

    fn accumulate_grads(&self, _params: &ParamSet, input: &[f32],
                        _tape: &Tape, dz: &[f32], grads: &mut [f32],
                        ctx: &mut Ctx) {
        let (b, m, n) = (self.batch, self.in_dim, self.out_dim);
        let own = &mut grads[self.range.clone()];
        let (db, dw) = own.split_at_mut(n);
        kernels::matmul_tn_acc(ctx.pool, input, dz, dw, m, b, n);
        for row in 0..b {
            for (j, dbj) in db.iter_mut().enumerate() {
                *dbj += dz[row * n + j];
            }
        }
    }

    fn input_grad(&self, params: &ParamSet, input: &[f32], _tape: &Tape,
                  dz: Vec<f32>, ctx: &mut Ctx) -> Option<Vec<f32>> {
        if self.first {
            ctx.arena.put(dz);
            return None;
        }
        let (b, m, n) = (self.batch, self.in_dim, self.out_dim);
        let w = params.slice(self.w_view);
        let mut dh = ctx.arena.take_zeroed(b * m);
        kernels::matmul_nt_acc(ctx.pool, &dz, w, &mut dh, b, n, m);
        if self.input_tanh {
            let dv = SharedMut::new(&mut dh);
            kernels::par_blocks(ctx.pool, b * m, |r| {
                let ds = unsafe { dv.range(r.clone()) };
                for (d, &h) in ds.iter_mut().zip(&input[r]) {
                    *d *= 1.0 - h * h;
                }
            });
        }
        ctx.arena.put(dz);
        Some(dh)
    }
}

/// The recurrent LSTM cell: consumes the whole `[B, T, F]` input,
/// produces the final hidden state `h_T` `[B, H]`. Backward runs the
/// entire BPTT loop inside `accumulate_grads` (the cell is the first
/// node, so there is no upstream gradient to split off).
struct LstmCellLayer {
    batch: usize,
    seq_len: usize,
    features: usize,
    hidden: usize,
    /// ParamSet view indices: lstm_b, lstm_wh, lstm_wx.
    bias_view: usize,
    wh_view: usize,
    wx_view: usize,
    range: Range<usize>,
}

impl Layer for LstmCellLayer {
    fn name(&self) -> &str {
        "lstm"
    }

    fn param_range(&self) -> Range<usize> {
        self.range.clone()
    }

    fn forward(&self, params: &ParamSet, input: &[f32],
               ctx: &mut Ctx) -> (Vec<f32>, Tape) {
        let (b, h, ff) = (self.batch, self.hidden, self.features);
        let bias = params.slice(self.bias_view);
        let wh = params.slice(self.wh_view);
        let wx = params.slice(self.wx_view);

        let mut hs = Vec::with_capacity(self.seq_len + 1);
        let mut cs = Vec::with_capacity(self.seq_len + 1);
        hs.push(ctx.arena.take_zeroed(b * h));
        cs.push(ctx.arena.take_zeroed(b * h));
        let mut gates = Vec::with_capacity(self.seq_len);
        let mut xt = ctx.arena.take_zeroed(b * ff);
        for t in 0..self.seq_len {
            step_input(input, t, b, self.seq_len, ff, &mut xt);
            let mut z = ctx.arena.take_zeroed(b * 4 * h);
            for row in 0..b {
                z[row * 4 * h..(row + 1) * 4 * h].copy_from_slice(bias);
            }
            kernels::matmul_acc(ctx.pool, &xt, wx, &mut z, b, ff, 4 * h);
            kernels::matmul_acc(ctx.pool, &hs[t], wh, &mut z, b, h,
                                4 * h);

            let mut gi = ctx.arena.take_zeroed(b * h);
            let mut gf = ctx.arena.take_zeroed(b * h);
            let mut gg = ctx.arena.take_zeroed(b * h);
            let mut go = ctx.arena.take_zeroed(b * h);
            let mut c_new = ctx.arena.take_zeroed(b * h);
            let mut h_new = ctx.arena.take_zeroed(b * h);
            {
                // Gate activations are per-element independent, so the
                // pooled blocks compute each k with the exact scalar op
                // sequence — bitwise-identical at any thread count.
                // Writes land in six disjoint output buffers at unique
                // k, so the element-wise views cannot alias.
                let c_prev: &[f32] = &cs[t];
                let zr: &[f32] = &z;
                let vi = SharedMut::new(&mut gi);
                let vf = SharedMut::new(&mut gf);
                let vg = SharedMut::new(&mut gg);
                let vo = SharedMut::new(&mut go);
                let vc = SharedMut::new(&mut c_new);
                let vh = SharedMut::new(&mut h_new);
                kernels::par_blocks(ctx.pool, b * h, |range| {
                    for k in range {
                        let row = k / h;
                        let j = k % h;
                        let zrow = &zr[row * 4 * h..(row + 1) * 4 * h];
                        let i = sigmoid(zrow[j]);
                        let f = sigmoid(zrow[h + j] + FORGET_BIAS);
                        let g = zrow[2 * h + j].tanh();
                        let o = sigmoid(zrow[3 * h + j]);
                        let c = f * c_prev[k] + i * g;
                        unsafe {
                            vi.write(k, i);
                            vf.write(k, f);
                            vg.write(k, g);
                            vo.write(k, o);
                            vc.write(k, c);
                            vh.write(k, o * c.tanh());
                        }
                    }
                });
            }
            ctx.arena.put(z);
            gates.push([gi, gf, gg, go]);
            hs.push(h_new);
            cs.push(c_new);
        }
        ctx.arena.put(xt);
        let out = hs.pop().unwrap();
        (out, Tape::Lstm { hs, cs, gates })
    }

    fn accumulate_grads(&self, params: &ParamSet, input: &[f32],
                        tape: &Tape, dz: &[f32], grads: &mut [f32],
                        ctx: &mut Ctx) {
        let Tape::Lstm { hs, cs, gates } = tape else {
            unreachable!("LSTM cell backward needs its recurrence tape")
        };
        let (b, h, ff) = (self.batch, self.hidden, self.features);
        let wh = params.slice(self.wh_view);

        // own gradient slices: bias [4H], wh [H,4H], wx [F,4H] — the
        // declaration-order layout inside this node's range
        let own = &mut grads[self.range.clone()];
        let (db, rest) = own.split_at_mut(4 * h);
        let (dwh, dwx) = rest.split_at_mut(h * 4 * h);

        // dh flowing into the last hidden state (from the head)
        let mut dh = ctx.arena.take_zeroed(b * h);
        dh.copy_from_slice(dz);
        let mut dc = ctx.arena.take_zeroed(b * h);
        let mut xt = ctx.arena.take_zeroed(b * ff);
        let mut dzg = ctx.arena.take_zeroed(b * 4 * h);
        for t in (0..self.seq_len).rev() {
            let [gi, gf, gg, go] = &gates[t];
            let c_new = &cs[t + 1];
            let c_prev = &cs[t];
            {
                // Per-element independent like the forward gate loop:
                // each k reads/writes only its own dc[k] and its own
                // four dzg slots (row/j are unique per k), so pooled
                // blocks keep the scalar op order bit for bit.
                let dhr: &[f32] = &dh;
                let vdz = SharedMut::new(&mut dzg);
                let vdc = SharedMut::new(&mut dc);
                kernels::par_blocks(ctx.pool, b * h, |range| {
                    for k in range {
                        let tc = c_new[k].tanh();
                        let dck = unsafe { vdc.read(k) }
                            + dhr[k] * go[k] * (1.0 - tc * tc);
                        let dok = dhr[k] * tc;
                        let row = k / h;
                        let j = k % h;
                        let zoff = row * 4 * h;
                        unsafe {
                            vdz.write(zoff + j,
                                      dck * gg[k] * gi[k] * (1.0 - gi[k]));
                            vdz.write(zoff + h + j,
                                      dck * c_prev[k] * gf[k]
                                          * (1.0 - gf[k]));
                            vdz.write(zoff + 2 * h + j,
                                      dck * gi[k] * (1.0 - gg[k] * gg[k]));
                            vdz.write(zoff + 3 * h + j,
                                      dok * go[k] * (1.0 - go[k]));
                            // carry to c_{t-1}; dh_{t-1} is recomputed
                            // below
                            vdc.write(k, dck * gf[k]);
                        }
                    }
                });
            }
            step_input(input, t, b, self.seq_len, ff, &mut xt);
            kernels::matmul_tn_acc(ctx.pool, &xt, &dzg, dwx, ff, b,
                                   4 * h);
            kernels::matmul_tn_acc(ctx.pool, &hs[t], &dzg, dwh, h, b,
                                   4 * h);
            for row in 0..b {
                for (j, dbj) in db.iter_mut().enumerate() {
                    *dbj += dzg[row * 4 * h + j];
                }
            }
            for v in dh.iter_mut() {
                *v = 0.0;
            }
            kernels::matmul_nt_acc(ctx.pool, &dzg, wh, &mut dh, b,
                                   4 * h, h);
        }
        ctx.arena.put(dh);
        ctx.arena.put(dc);
        ctx.arena.put(xt);
        ctx.arena.put(dzg);
    }

    fn input_grad(&self, _params: &ParamSet, _input: &[f32],
                  _tape: &Tape, dz: Vec<f32>, ctx: &mut Ctx)
        -> Option<Vec<f32>> {
        // first node: gradients w.r.t. the raw input are not needed
        ctx.arena.put(dz);
        None
    }
}

// ---------------------------------------------------------------------------
// model construction
// ---------------------------------------------------------------------------

/// (offset, len) of each manifest parameter in the flat vector, in
/// declaration order (the [`ParamSet`] layout).
fn view_layout(params: &[(String, Vec<usize>)]) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(params.len());
    let mut off = 0usize;
    for (_, shape) in params {
        let len: usize = shape.iter().product();
        out.push((off, len));
        off += len;
    }
    out
}

impl NativeModel {
    /// Build from a manifest entry, validating that the parameter table
    /// matches what this backend can execute.
    pub(crate) fn from_meta(meta: &ModelMeta)
        -> Result<NativeModel, RuntimeError> {
        let dag = match meta.model.as_str() {
            "mlp" => MlpNet::from_meta(meta)?.into_dag(meta),
            "lstm" => LstmNet::from_meta(meta)?.into_dag(meta),
            other => {
                return Err(RuntimeError::Unsupported(format!(
                    "model family '{other}' needs the PJRT backend \
                     (native backend supports mlp and lstm)"
                )))
            }
        };
        Ok(NativeModel {
            dag,
            arenas: Mutex::new(Vec::new()),
            reuse_scratch: AtomicBool::new(true),
            pool: Mutex::new(Arc::new(ThreadPool::new(1))),
        })
    }

    /// Run `f` on a pooled arena (or a throwaway one when reuse is
    /// off) plus the current compute pool. The arena pool holds one
    /// arena per concurrent caller, so threads never contend on buffer
    /// contents; the compute pool is shared (its submit lock
    /// serializes concurrent steps' parallel loops).
    fn with_ctx<R>(&self, f: impl FnOnce(&mut Ctx) -> R) -> R {
        let pool = self.pool.lock().unwrap().clone();
        let reuse = self.reuse_scratch.load(Ordering::Relaxed);
        let mut arena = if reuse {
            self.arenas.lock().unwrap().pop().unwrap_or_else(Arena::new)
        } else {
            Arena::new()
        };
        let out = f(&mut Ctx { arena: &mut arena, pool: &pool });
        if reuse {
            self.arenas.lock().unwrap().push(arena);
        }
        out
    }

    /// Toggle scratch-buffer pooling (on by default). Turning it off
    /// drops the pool — the `runtime_microbench` baseline mode.
    pub(crate) fn set_scratch_reuse(&self, on: bool) {
        self.reuse_scratch.store(on, Ordering::Relaxed);
        if !on {
            self.arenas.lock().unwrap().clear();
        }
    }

    /// Resize the compute pool (`0` = auto: the host's available
    /// parallelism). Safe at any point between steps; results are
    /// bitwise-identical at every size, so this is purely a throughput
    /// knob. No-op when the pool already has the requested size.
    pub(crate) fn set_threads(&self, n: usize) {
        let target = if n == 0 { ThreadPool::auto_threads() } else { n };
        let mut pool = self.pool.lock().unwrap();
        if pool.threads() != target {
            *pool = Arc::new(ThreadPool::new(target));
        }
    }

    /// The live compute pool (for the optimizer step loops and the
    /// wire codec, which share it — see DESIGN.md §Compute kernels).
    pub(crate) fn thread_pool(&self) -> Arc<ThreadPool> {
        self.pool.lock().unwrap().clone()
    }

    pub(crate) fn grad_step(&self, params: &ParamSet, x: &[f32],
                            y: &[i32]) -> Result<GradOutput, RuntimeError> {
        self.grad_step_overlapped(params, x, y, &mut ())
    }

    /// [`NativeModel::grad_step`] with per-layer [`BucketReady`]
    /// emission: `sink` fires in reverse topological order, each event
    /// as soon as that layer's gradient slice is final.
    pub(crate) fn grad_step_overlapped(&self, params: &ParamSet,
                                       x: &[f32], y: &[i32],
                                       sink: &mut dyn GradSink)
        -> Result<GradOutput, RuntimeError> {
        Ok(self.with_ctx(|ctx| self.dag.grad(params, x, y, ctx, sink)))
    }

    pub(crate) fn eval_step(&self, params: &ParamSet, x: &[f32],
                            y: &[i32]) -> Result<(f32, f32), RuntimeError> {
        let (batch, classes) = self.out_shape();
        Ok(self.with_ctx(|ctx| {
            let logits = self.dag.logits(params, x, ctx);
            let (loss, _) = softmax_xent_grad(&logits, y, batch, classes);
            let ncorrect = argmax_correct(&logits, y, batch, classes);
            ctx.arena.put(logits);
            (loss, ncorrect)
        }))
    }

    pub(crate) fn predict(&self, params: &ParamSet, x: &[f32])
        -> Result<Vec<f32>, RuntimeError> {
        Ok(self.with_ctx(|ctx| self.dag.logits(params, x, ctx)))
    }

    fn out_shape(&self) -> (usize, usize) {
        (self.dag.batch, self.dag.classes)
    }
}

/// Synthesize the manifest entry for a natively-supported variant key
/// (`mlp_b100`, `lstm_b10`, …) using the quickstart/paper architecture
/// constants from `python/compile/model.py`. Returns `None` for keys the
/// native backend cannot serve.
pub(crate) fn meta_for_key(key: &str) -> Option<ModelMeta> {
    let (family, batch_s) = key.rsplit_once("_b")?;
    let batch: usize = batch_s.parse().ok()?;
    if batch == 0 {
        return None;
    }
    let (seq_len, features, classes, hidden) = (30usize, 16usize, 3usize,
                                                20usize);
    let params: Vec<(String, Vec<usize>)> = match family {
        "mlp" => {
            let dims = [seq_len * features, 64, 32, classes];
            let mut p = Vec::new();
            for li in 0..dims.len() - 1 {
                p.push((format!("fc{li}_b"), vec![dims[li + 1]]));
                p.push((format!("fc{li}_w"), vec![dims[li], dims[li + 1]]));
            }
            p
        }
        "lstm" => vec![
            ("lstm_b".into(), vec![4 * hidden]),
            ("lstm_wh".into(), vec![hidden, 4 * hidden]),
            ("lstm_wx".into(), vec![features, 4 * hidden]),
            ("out_b".into(), vec![classes]),
            ("out_w".into(), vec![hidden, classes]),
        ],
        _ => return None,
    };
    let param_count = params
        .iter()
        .map(|(_, s)| s.iter().product::<usize>())
        .sum();
    Some(ModelMeta {
        key: key.to_string(),
        model: family.to_string(),
        batch,
        seq_len,
        features,
        classes,
        hidden,
        params,
        param_count,
        grad_file: std::path::PathBuf::from("native"),
        eval_file: std::path::PathBuf::from("native"),
        predict_file: std::path::PathBuf::from("native"),
    })
}

// ---------------------------------------------------------------------------
// MLP
// ---------------------------------------------------------------------------

impl MlpNet {
    fn from_meta(meta: &ModelMeta) -> Result<MlpNet, RuntimeError> {
        let bad = |msg: String| RuntimeError::Unsupported(msg);
        if meta.params.len() < 2 || meta.params.len() % 2 != 0 {
            return Err(bad(format!(
                "mlp '{}': expected fc{{i}}_b/fc{{i}}_w parameter pairs",
                meta.key
            )));
        }
        let n_layers = meta.params.len() / 2;
        let mut dims = vec![meta.seq_len * meta.features];
        for li in 0..n_layers {
            let (bname, bshape) = &meta.params[2 * li];
            let (wname, wshape) = &meta.params[2 * li + 1];
            if bname != &format!("fc{li}_b") || wname != &format!("fc{li}_w")
                || wshape.len() != 2 || bshape.len() != 1
                || wshape[0] != dims[li] || wshape[1] != bshape[0]
            {
                return Err(bad(format!(
                    "mlp '{}': unexpected parameter table at layer {li}",
                    meta.key
                )));
            }
            dims.push(wshape[1]);
        }
        if *dims.last().unwrap() != meta.classes {
            return Err(bad(format!(
                "mlp '{}': output width != classes", meta.key
            )));
        }
        Ok(MlpNet { batch: meta.batch, dims })
    }

    /// One `DenseLayer` node per fc pair (tanh on hidden layers).
    fn into_dag(self, meta: &ModelMeta) -> LayerDag {
        let views = view_layout(&meta.params);
        let n_layers = self.dims.len() - 1;
        let mut nodes: Vec<Box<dyn Layer + Send + Sync>> =
            Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            let (boff, blen) = views[2 * li];
            let (woff, wlen) = views[2 * li + 1];
            debug_assert_eq!(boff + blen, woff,
                             "bias must precede its weight contiguously");
            nodes.push(Box::new(DenseLayer {
                name: format!("fc{li}"),
                batch: self.batch,
                in_dim: self.dims[li],
                out_dim: self.dims[li + 1],
                bias_view: 2 * li,
                w_view: 2 * li + 1,
                range: boff..woff + wlen,
                tanh: li < n_layers - 1,
                input_tanh: li > 0,
                first: li == 0,
            }));
        }
        LayerDag {
            nodes,
            batch: self.batch,
            classes: *self.dims.last().unwrap(),
        }
    }
}

/// Monolithic MLP reference: the pre-DAG single-function forward and
/// backward, kept as the oracle for the monolith-vs-DAG bitwise
/// equality test.
#[cfg(test)]
impl MlpNet {
    /// Forward pass; returns activations per layer (acts[0] = flat x,
    /// acts.last() = logits; hidden activations are post-tanh).
    fn forward(&self, params: &ParamSet, x: &[f32]) -> Vec<Vec<f32>> {
        let b = self.batch;
        let n_layers = self.dims.len() - 1;
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers + 1);
        acts.push(x.to_vec());
        for li in 0..n_layers {
            let bias = params.slice(2 * li);
            let w = params.slice(2 * li + 1);
            let (m, n) = (self.dims[li], self.dims[li + 1]);
            let mut z = vec![0.0f32; b * n];
            for row in 0..b {
                z[row * n..(row + 1) * n].copy_from_slice(bias);
            }
            matmul_acc(&acts[li], w, &mut z, b, m, n);
            if li < n_layers - 1 {
                for v in &mut z {
                    *v = v.tanh();
                }
            }
            acts.push(z);
        }
        acts
    }

    fn grad(&self, params: &ParamSet, x: &[f32], y: &[i32]) -> GradOutput {
        let b = self.batch;
        let n_layers = self.dims.len() - 1;
        let classes = *self.dims.last().unwrap();
        let acts = self.forward(params, x);
        let (loss, mut dz) = softmax_xent_grad(acts.last().unwrap(), y, b,
                                               classes);
        let mut grads = grad_buffer(params.num_params());
        let views = params.views();
        for li in (0..n_layers).rev() {
            let (m, n) = (self.dims[li], self.dims[li + 1]);
            let bv = &views[2 * li];
            let wv = &views[2 * li + 1];
            matmul_tn_acc(&acts[li], &dz,
                          &mut grads[wv.offset..wv.offset + wv.len],
                          m, b, n);
            let db = &mut grads[bv.offset..bv.offset + bv.len];
            for row in 0..b {
                for (j, dbj) in db.iter_mut().enumerate() {
                    *dbj += dz[row * n + j];
                }
            }
            if li > 0 {
                let w = params.slice(2 * li + 1);
                let mut dh = vec![0.0f32; b * m];
                matmul_nt_acc(&dz, w, &mut dh, b, n, m);
                for (d, &h) in dh.iter_mut().zip(&acts[li]) {
                    *d *= 1.0 - h * h;
                }
                dz = dh;
            }
        }
        GradOutput { loss, grads }
    }
}

// ---------------------------------------------------------------------------
// LSTM
// ---------------------------------------------------------------------------

/// Forward-pass state kept for backprop-through-time (monolithic
/// reference path).
#[cfg(test)]
struct LstmForward {
    logits: Vec<f32>,
    /// h[t] for t = 0..=T (h[0] is the zero initial state), each [B, H].
    hs: Vec<Vec<f32>>,
    /// c[t] for t = 0..=T, each [B, H].
    cs: Vec<Vec<f32>>,
    /// Per-step activated gates (i, f, g, o), each [B, H].
    gates: Vec<[Vec<f32>; 4]>,
}

impl LstmNet {
    fn from_meta(meta: &ModelMeta) -> Result<LstmNet, RuntimeError> {
        let h = meta.hidden;
        let expect: Vec<(String, Vec<usize>)> = vec![
            ("lstm_b".into(), vec![4 * h]),
            ("lstm_wh".into(), vec![h, 4 * h]),
            ("lstm_wx".into(), vec![meta.features, 4 * h]),
            ("out_b".into(), vec![meta.classes]),
            ("out_w".into(), vec![h, meta.classes]),
        ];
        if meta.params != expect {
            return Err(RuntimeError::Unsupported(format!(
                "lstm '{}': parameter table does not match the \
                 single-layer LSTM this backend implements",
                meta.key
            )));
        }
        Ok(LstmNet {
            batch: meta.batch,
            seq_len: meta.seq_len,
            features: meta.features,
            hidden: h,
            classes: meta.classes,
        })
    }

    /// Two nodes: the recurrent cell (views 0-2), then the linear head
    /// (views 3-4).
    fn into_dag(self, meta: &ModelMeta) -> LayerDag {
        let views = view_layout(&meta.params);
        let cell_end = views[2].0 + views[2].1;
        let nodes: Vec<Box<dyn Layer + Send + Sync>> = vec![
            Box::new(LstmCellLayer {
                batch: self.batch,
                seq_len: self.seq_len,
                features: self.features,
                hidden: self.hidden,
                bias_view: 0,
                wh_view: 1,
                wx_view: 2,
                range: 0..cell_end,
            }),
            Box::new(DenseLayer {
                name: "out".into(),
                batch: self.batch,
                in_dim: self.hidden,
                out_dim: self.classes,
                bias_view: 3,
                w_view: 4,
                range: views[3].0..views[4].0 + views[4].1,
                tanh: false,
                input_tanh: false,
                first: false,
            }),
        ];
        LayerDag {
            nodes,
            batch: self.batch,
            classes: self.classes,
        }
    }
}

/// Monolithic LSTM reference (pre-DAG), kept as the oracle for the
/// monolith-vs-DAG bitwise equality test.
#[cfg(test)]
impl LstmNet {
    fn forward(&self, params: &ParamSet, x: &[f32]) -> LstmForward {
        let (b, h, ff) = (self.batch, self.hidden, self.features);
        let bias = params.slice(0);
        let wh = params.slice(1);
        let wx = params.slice(2);
        let out_b = params.slice(3);
        let out_w = params.slice(4);

        let mut hs = vec![vec![0.0f32; b * h]];
        let mut cs = vec![vec![0.0f32; b * h]];
        let mut gates = Vec::with_capacity(self.seq_len);
        let mut xt = vec![0.0f32; b * ff];
        for t in 0..self.seq_len {
            step_input(x, t, b, self.seq_len, ff, &mut xt);
            let mut z = vec![0.0f32; b * 4 * h];
            for row in 0..b {
                z[row * 4 * h..(row + 1) * 4 * h].copy_from_slice(bias);
            }
            matmul_acc(&xt, wx, &mut z, b, ff, 4 * h);
            matmul_acc(&hs[t], wh, &mut z, b, h, 4 * h);

            let mut gi = vec![0.0f32; b * h];
            let mut gf = vec![0.0f32; b * h];
            let mut gg = vec![0.0f32; b * h];
            let mut go = vec![0.0f32; b * h];
            let mut c_new = vec![0.0f32; b * h];
            let mut h_new = vec![0.0f32; b * h];
            let c_prev = &cs[t];
            for row in 0..b {
                for j in 0..h {
                    let zrow = &z[row * 4 * h..(row + 1) * 4 * h];
                    let k = row * h + j;
                    let i = sigmoid(zrow[j]);
                    let f = sigmoid(zrow[h + j] + FORGET_BIAS);
                    let g = zrow[2 * h + j].tanh();
                    let o = sigmoid(zrow[3 * h + j]);
                    let c = f * c_prev[k] + i * g;
                    gi[k] = i;
                    gf[k] = f;
                    gg[k] = g;
                    go[k] = o;
                    c_new[k] = c;
                    h_new[k] = o * c.tanh();
                }
            }
            gates.push([gi, gf, gg, go]);
            hs.push(h_new);
            cs.push(c_new);
        }

        let mut logits = vec![0.0f32; b * self.classes];
        for row in 0..b {
            logits[row * self.classes..(row + 1) * self.classes]
                .copy_from_slice(out_b);
        }
        matmul_acc(hs.last().unwrap(), out_w, &mut logits, b, h,
                   self.classes);
        LstmForward { logits, hs, cs, gates }
    }

    fn grad(&self, params: &ParamSet, x: &[f32], y: &[i32]) -> GradOutput {
        let (b, h, ff, c_out) = (self.batch, self.hidden, self.features,
                                 self.classes);
        let fwd = self.forward(params, x);
        let (loss, dlogits) = softmax_xent_grad(&fwd.logits, y, b, c_out);

        let views = params.views();
        let mut grads = grad_buffer(params.num_params());
        let wh = params.slice(1);
        let out_w = params.slice(4);

        // head: out_w [H, C], out_b [C]
        {
            let wv = &views[4];
            matmul_tn_acc(fwd.hs.last().unwrap(), &dlogits,
                          &mut grads[wv.offset..wv.offset + wv.len],
                          h, b, c_out);
            let bv = &views[3];
            let db = &mut grads[bv.offset..bv.offset + bv.len];
            for row in 0..b {
                for (j, dbj) in db.iter_mut().enumerate() {
                    *dbj += dlogits[row * c_out + j];
                }
            }
        }

        // dh flowing into the last hidden state
        let mut dh = vec![0.0f32; b * h];
        matmul_nt_acc(&dlogits, out_w, &mut dh, b, c_out, h);
        let mut dc = vec![0.0f32; b * h];

        let mut xt = vec![0.0f32; b * ff];
        let mut dz = vec![0.0f32; b * 4 * h];
        for t in (0..self.seq_len).rev() {
            let [gi, gf, gg, go] = &fwd.gates[t];
            let c_new = &fwd.cs[t + 1];
            let c_prev = &fwd.cs[t];
            for k in 0..b * h {
                let tc = c_new[k].tanh();
                let dck = dc[k] + dh[k] * go[k] * (1.0 - tc * tc);
                let dok = dh[k] * tc;
                let row = k / h;
                let j = k % h;
                let zrow = &mut dz[row * 4 * h..(row + 1) * 4 * h];
                zrow[j] = dck * gg[k] * gi[k] * (1.0 - gi[k]);
                zrow[h + j] = dck * c_prev[k] * gf[k] * (1.0 - gf[k]);
                zrow[2 * h + j] = dck * gi[k] * (1.0 - gg[k] * gg[k]);
                zrow[3 * h + j] = dok * go[k] * (1.0 - go[k]);
                // carry to c_{t-1}; dh_{t-1} is recomputed below
                dc[k] = dck * gf[k];
            }
            step_input(x, t, b, self.seq_len, ff, &mut xt);
            // lstm_wx [F, 4H] at view 2, lstm_wh [H, 4H] at view 1,
            // lstm_b [4H] at view 0
            {
                let wv = &views[2];
                matmul_tn_acc(&xt, &dz,
                              &mut grads[wv.offset..wv.offset + wv.len],
                              ff, b, 4 * h);
            }
            {
                let wv = &views[1];
                matmul_tn_acc(&fwd.hs[t], &dz,
                              &mut grads[wv.offset..wv.offset + wv.len],
                              h, b, 4 * h);
            }
            {
                let bv = &views[0];
                let db = &mut grads[bv.offset..bv.offset + bv.len];
                for row in 0..b {
                    for (j, dbj) in db.iter_mut().enumerate() {
                        *dbj += dz[row * 4 * h + j];
                    }
                }
            }
            for v in dh.iter_mut() {
                *v = 0.0;
            }
            matmul_nt_acc(&dz, wh, &mut dh, b, 4 * h, h);
        }
        GradOutput { loss, grads }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_inputs(meta: &ModelMeta, seed: u64)
        -> (ParamSet, Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let params = ParamSet::glorot_init(&meta.params, &mut rng);
        let x: Vec<f32> = (0..meta.batch * meta.seq_len * meta.features)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let y: Vec<i32> = (0..meta.batch)
            .map(|_| rng.usize_below(meta.classes) as i32)
            .collect();
        (params, x, y)
    }

    fn fd_check(meta: &ModelMeta, model: &NativeModel, probes: usize) {
        // Directional finite difference in f32: the whole-gradient
        // projection is much more stable than per-coordinate probes.
        let mut rng = Rng::new(42);
        let params = ParamSet::glorot_init(&meta.params, &mut rng);
        let x: Vec<f32> = (0..meta.batch * meta.seq_len * meta.features)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let y: Vec<i32> = (0..meta.batch)
            .map(|_| rng.usize_below(meta.classes) as i32)
            .collect();
        let out = model.grad_step(&params, &x, &y).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        assert_eq!(out.grads.len(), meta.param_count);
        for _ in 0..probes {
            let dir: Vec<f32> = (0..params.num_params())
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let eps = 1e-3f32;
            let mut plus = params.clone();
            plus.axpy(eps, &dir);
            let mut minus = params.clone();
            minus.axpy(-eps, &dir);
            let (lp, _) = model.eval_step(&plus, &x, &y).unwrap();
            let (lm, _) = model.eval_step(&minus, &x, &y).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            let analytic: f32 =
                out.grads.iter().zip(&dir).map(|(g, d)| g * d).sum();
            let denom = fd.abs().max(analytic.abs()).max(1e-3);
            assert!(
                (fd - analytic).abs() / denom < 0.05,
                "fd={fd} analytic={analytic}"
            );
        }
    }

    #[test]
    fn meta_for_key_matches_known_param_counts() {
        let mlp = meta_for_key("mlp_b100").unwrap();
        assert_eq!(mlp.param_count, 32_963);
        assert_eq!(mlp.batch, 100);
        let lstm = meta_for_key("lstm_b10").unwrap();
        assert_eq!(lstm.param_count, 3_023);
        assert!(meta_for_key("transformer_b16").is_none());
        assert!(meta_for_key("garbage").is_none());
        assert!(meta_for_key("mlp_b0").is_none());
    }

    #[test]
    fn mlp_gradient_matches_finite_difference() {
        let meta = meta_for_key("mlp_b10").unwrap();
        let model = NativeModel::from_meta(&meta).unwrap();
        fd_check(&meta, &model, 3);
    }

    #[test]
    fn lstm_gradient_matches_finite_difference() {
        let meta = meta_for_key("lstm_b10").unwrap();
        let model = NativeModel::from_meta(&meta).unwrap();
        fd_check(&meta, &model, 3);
    }

    #[test]
    fn per_layer_gradient_matches_finite_difference() {
        // Directional FD restricted to ONE layer's parameter range at a
        // time: validates each DAG node's accumulate_grads in isolation
        // (a whole-model probe can hide one layer's error behind the
        // others' mass).
        for key in ["mlp_b10", "lstm_b10"] {
            let meta = meta_for_key(key).unwrap();
            let model = NativeModel::from_meta(&meta).unwrap();
            let (params, x, y) = test_inputs(&meta, 42);
            let out = model.grad_step(&params, &x, &y).unwrap();
            let mut rng = Rng::new(171);
            for (name, range) in params.layer_ranges() {
                let mut dir = vec![0.0f32; params.num_params()];
                for v in &mut dir[range.clone()] {
                    *v = rng.normal_f32(0.0, 1.0);
                }
                let eps = 1e-3f32;
                let mut plus = params.clone();
                plus.axpy(eps, &dir);
                let mut minus = params.clone();
                minus.axpy(-eps, &dir);
                let (lp, _) = model.eval_step(&plus, &x, &y).unwrap();
                let (lm, _) = model.eval_step(&minus, &x, &y).unwrap();
                let fd = (lp - lm) / (2.0 * eps);
                // dir is zero outside the layer range, so the full
                // projection IS the per-layer projection
                let analytic: f32 = out
                    .grads
                    .iter()
                    .zip(&dir)
                    .map(|(g, d)| g * d)
                    .sum();
                let denom = fd.abs().max(analytic.abs()).max(1e-3);
                assert!(
                    (fd - analytic).abs() / denom < 0.05,
                    "{key} layer {name}: fd={fd} analytic={analytic}"
                );
            }
        }
    }

    #[test]
    fn dag_gradients_match_monolith_bitwise() {
        // The DAG is a pure restructuring of the monolithic backward:
        // identical op sequence, so loss AND every gradient element
        // must match bit for bit.
        let meta = meta_for_key("mlp_b10").unwrap();
        let model = NativeModel::from_meta(&meta).unwrap();
        let mono = MlpNet::from_meta(&meta).unwrap();
        let (params, x, y) = test_inputs(&meta, 1234);
        let dag_out = model.grad_step(&params, &x, &y).unwrap();
        let mono_out = mono.grad(&params, &x, &y);
        assert_eq!(dag_out.loss.to_bits(), mono_out.loss.to_bits());
        assert!(dag_out
            .grads
            .iter()
            .zip(&mono_out.grads)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
                "mlp DAG gradient diverged from the monolith");

        let meta = meta_for_key("lstm_b10").unwrap();
        let model = NativeModel::from_meta(&meta).unwrap();
        let mono = LstmNet::from_meta(&meta).unwrap();
        let (params, x, y) = test_inputs(&meta, 5678);
        let dag_out = model.grad_step(&params, &x, &y).unwrap();
        let mono_out = mono.grad(&params, &x, &y);
        assert_eq!(dag_out.loss.to_bits(), mono_out.loss.to_bits());
        assert!(dag_out
            .grads
            .iter()
            .zip(&mono_out.grads)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
                "lstm DAG gradient diverged from the monolith");
    }

    /// Sink recording every emission plus a snapshot of the emitted
    /// slice at emission time.
    struct RecordingSink {
        events: Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
    }

    impl GradSink for RecordingSink {
        fn bucket_ready(&mut self, ready: BucketReady, grads: &[f32]) {
            let snap = grads[ready.param_range.clone()].to_vec();
            self.events.push((ready.layer, ready.param_range, snap));
        }
    }

    #[test]
    fn bucket_ready_fires_reverse_order_with_final_slices() {
        // Emission order must be the reverse of layer_ranges (output
        // layer first), and each emitted slice must already hold its
        // FINAL value — that is the entire basis of the overlap.
        for key in ["mlp_b10", "lstm_b10"] {
            let meta = meta_for_key(key).unwrap();
            let model = NativeModel::from_meta(&meta).unwrap();
            let (params, x, y) = test_inputs(&meta, 99);
            let mut sink = RecordingSink { events: Vec::new() };
            let out = model
                .grad_step_overlapped(&params, &x, &y, &mut sink)
                .unwrap();
            let ranges = params.layer_ranges();
            assert_eq!(sink.events.len(), ranges.len(), "{key}");
            for (ev, (name, range)) in
                sink.events.iter().zip(ranges.iter().rev())
            {
                assert_eq!(&ev.1, range, "{key} layer {name}");
                assert!(ev.2
                    .iter()
                    .zip(&out.grads[range.clone()])
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{key} layer {name}: slice not final at \
                         emission");
            }
            for w in sink.events.windows(2) {
                assert!(w[0].0 > w[1].0,
                        "{key}: layer ids must descend");
            }
        }
    }

    #[test]
    fn layer_backward_composes_the_two_halves() {
        // The provided Layer::backward must equal accumulate_grads
        // followed by input_grad — the DAG relies on that split being a
        // pure refactoring of the combined step.
        let meta = meta_for_key("mlp_b10").unwrap();
        let dag = MlpNet::from_meta(&meta).unwrap().into_dag(&meta);
        let (params, x, y) = test_inputs(&meta, 11);
        let pool = ThreadPool::new(1);
        let mut arena = Arena::new();
        let mut ctx = Ctx { arena: &mut arena, pool: &pool };
        let (acts, tapes) = dag.forward(&params, &x, &mut ctx);
        let (_, dz) = softmax_xent_grad(acts.last().unwrap(), &y,
                                        meta.batch, meta.classes);
        let last = dag.nodes.len() - 1;
        let node = &dag.nodes[last];
        let input = &acts[last - 1];
        let mut split = grad_buffer(params.num_params());
        node.accumulate_grads(&params, input, &tapes[last], &dz,
                              &mut split, &mut ctx);
        let d_split = node
            .input_grad(&params, input, &tapes[last], dz.clone(),
                        &mut ctx)
            .unwrap();
        let mut combined = grad_buffer(params.num_params());
        let d_combined = node
            .backward(&params, input, &tapes[last], dz, &mut combined,
                      &mut ctx)
            .unwrap();
        assert!(split
            .iter()
            .zip(&combined)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(d_split
            .iter()
            .zip(&d_combined)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn scratch_reuse_does_not_change_results() {
        // Cold arena, warm arena, and reuse-off must all produce
        // bitwise-identical gradients (take_zeroed guarantees buffer
        // history cannot leak into values).
        for key in ["mlp_b10", "lstm_b10"] {
            let meta = meta_for_key(key).unwrap();
            let model = NativeModel::from_meta(&meta).unwrap();
            let (params, x, y) = test_inputs(&meta, 31);
            let cold = model.grad_step(&params, &x, &y).unwrap();
            let warm = model.grad_step(&params, &x, &y).unwrap();
            model.set_scratch_reuse(false);
            let fresh = model.grad_step(&params, &x, &y).unwrap();
            model.set_scratch_reuse(true);
            for other in [&warm, &fresh] {
                assert_eq!(cold.loss.to_bits(), other.loss.to_bits(),
                           "{key}");
                assert!(cold
                    .grads
                    .iter()
                    .zip(&other.grads)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{key}: arena reuse changed the gradient");
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_gradients() {
        // The entire compute-engine contract in one place: loss and
        // every gradient element bitwise-identical across pool sizes
        // (1 = the legacy inline path).
        for key in ["mlp_b10", "lstm_b10", "mlp_b100"] {
            let meta = meta_for_key(key).unwrap();
            let model = NativeModel::from_meta(&meta).unwrap();
            let (params, x, y) = test_inputs(&meta, 4096);
            let base = model.grad_step(&params, &x, &y).unwrap();
            for threads in [2usize, 4, 1] {
                model.set_threads(threads);
                let out = model.grad_step(&params, &x, &y).unwrap();
                assert_eq!(base.loss.to_bits(), out.loss.to_bits(),
                           "{key} t={threads}");
                assert!(base
                    .grads
                    .iter()
                    .zip(&out.grads)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{key} t={threads}: gradient depends on the \
                         thread count");
            }
        }
    }

    #[test]
    fn eval_counts_correct_predictions() {
        let meta = meta_for_key("mlp_b10").unwrap();
        let model = NativeModel::from_meta(&meta).unwrap();
        let mut rng = Rng::new(1);
        let params = ParamSet::glorot_init(&meta.params, &mut rng);
        let x = vec![0.1f32; meta.batch * meta.seq_len * meta.features];
        let y = vec![0i32; meta.batch];
        let (loss, ncorrect) = model.eval_step(&params, &x, &y).unwrap();
        assert!(loss.is_finite());
        assert!((0.0..=meta.batch as f32).contains(&ncorrect));
        let logits = model.predict(&params, &x).unwrap();
        assert_eq!(logits.len(), meta.batch * meta.classes);
    }

    #[test]
    fn gradient_descent_reduces_loss() {
        // A few plain-SGD steps on one fixed batch must reduce the loss
        // for both families — end-to-end backprop sanity.
        for key in ["mlp_b10", "lstm_b10"] {
            let meta = meta_for_key(key).unwrap();
            let model = NativeModel::from_meta(&meta).unwrap();
            let mut rng = Rng::new(7);
            let mut params = ParamSet::glorot_init(&meta.params, &mut rng);
            let x: Vec<f32> = (0..meta.batch * meta.seq_len * meta.features)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let y: Vec<i32> = (0..meta.batch)
                .map(|_| rng.usize_below(meta.classes) as i32)
                .collect();
            let first = model.grad_step(&params, &x, &y).unwrap();
            let mut last = first.loss;
            for _ in 0..50 {
                let out = model.grad_step(&params, &x, &y).unwrap();
                params.axpy(-0.1, &out.grads);
                last = out.loss;
            }
            assert!(
                last < first.loss * 0.6,
                "{key}: loss {} -> {last} did not drop",
                first.loss
            );
        }
    }
}
