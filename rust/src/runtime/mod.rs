//! Model runtime: artifact manifest + executable backends.
//!
//! `Session` is the convenience entry point used by the coordinator,
//! examples, and benches: open the artifact dir (or fall back to the
//! built-in native backend), pick a model variant, get shared (`Arc`)
//! executables for the training world's threads.
//!
//! Backend selection:
//! - artifacts on disk + `pjrt` feature → compiled HLO through PJRT;
//! - artifacts on disk, default build → the native engine re-executes
//!   the manifest's models (same math, see [`native`]);
//! - no artifacts at all → [`Session::native`] synthesizes the
//!   quickstart/paper variants (`mlp_b*`, `lstm_b*`) on demand, so a
//!   fresh checkout trains end-to-end with zero setup.

pub mod artifact;
pub mod executor;
pub(crate) mod kernels;
pub(crate) mod native;

pub use artifact::{default_artifact_dir, ArtifactError, Manifest,
                   ModelMeta};
pub use executor::{BucketReady, Client, GradOutput, GradSink,
                   ModelExecutables, RuntimeError};
pub use kernels::kernel_gflops;
#[cfg(feature = "pjrt")]
pub use executor::Executable;

use std::path::{Path, PathBuf};
use std::sync::Arc;

#[derive(Debug)]
pub enum SessionError {
    Artifact(ArtifactError),
    Runtime(RuntimeError),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Artifact(e) => e.fmt(f),
            SessionError::Runtime(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for SessionError {}

impl From<ArtifactError> for SessionError {
    fn from(e: ArtifactError) -> Self {
        SessionError::Artifact(e)
    }
}

impl From<RuntimeError> for SessionError {
    fn from(e: RuntimeError) -> Self {
        SessionError::Runtime(e)
    }
}

/// Artifact dir + execution client + compile cache.
pub struct Session {
    pub manifest: Manifest,
    pub client: Arc<Client>,
    /// Synthesize native variants for keys the manifest lacks.
    native_fallback: bool,
    cache: std::sync::Mutex<
        std::collections::BTreeMap<String, Arc<ModelExecutables>>>,
}

impl Session {
    /// Open an on-disk artifact directory (`meta.json` + HLO files).
    pub fn open(artifact_dir: &Path) -> Result<Session, SessionError> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = Client::cpu()?;
        Ok(Session {
            manifest,
            client,
            native_fallback: false,
            cache: std::sync::Mutex::new(Default::default()),
        })
    }

    /// A session with no artifacts: every variant is synthesized and
    /// executed by the native backend.
    pub fn native() -> Result<Session, SessionError> {
        Ok(Session {
            manifest: Manifest {
                dir: PathBuf::from("native"),
                models: Vec::new(),
            },
            client: Client::cpu()?,
            native_fallback: true,
            cache: std::sync::Mutex::new(Default::default()),
        })
    }

    /// Open the default artifact dir (`$MPI_LEARN_ARTIFACTS` or
    /// `./artifacts`), falling back to the native session when no
    /// manifest exists there.
    pub fn open_default() -> Result<Session, SessionError> {
        let dir = default_artifact_dir();
        if dir.join("meta.json").exists() {
            Self::open(&dir)
        } else {
            Self::native()
        }
    }

    #[cfg(feature = "pjrt")]
    fn build(&self, meta: &ModelMeta)
        -> Result<ModelExecutables, SessionError> {
        Ok(ModelExecutables::load(&self.client, meta, true)?)
    }

    /// No PJRT in this build: the native engine executes the manifest's
    /// model (families it knows) instead.
    #[cfg(not(feature = "pjrt"))]
    fn build(&self, meta: &ModelMeta)
        -> Result<ModelExecutables, SessionError> {
        Ok(ModelExecutables::native(meta)?)
    }

    /// Executables for a manifest key like `lstm_b100` (compiled once,
    /// then cached).
    pub fn executables(&self, key: &str)
        -> Result<Arc<ModelExecutables>, SessionError> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exes) = cache.get(key) {
                return Ok(exes.clone());
            }
        }
        let exes = match self.manifest.get(key) {
            Ok(meta) => {
                let meta = meta.clone();
                Arc::new(self.build(&meta)?)
            }
            Err(ArtifactError::UnknownVariant(_)) if self.native_fallback => {
                let meta = native::meta_for_key(key).ok_or_else(|| {
                    ArtifactError::UnknownVariant(key.to_string())
                })?;
                Arc::new(ModelExecutables::native(&meta)?)
            }
            Err(e) => return Err(e.into()),
        };
        self.cache
            .lock()
            .unwrap()
            .insert(key.to_string(), exes.clone());
        Ok(exes)
    }

    /// Variant lookup by (model, batch).
    pub fn executables_for(&self, model: &str, batch: usize)
        -> Result<Arc<ModelExecutables>, SessionError> {
        self.executables(&format!("{model}_b{batch}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_session_serves_quickstart_variants() {
        let s = Session::native().unwrap();
        let exes = s.executables("mlp_b10").unwrap();
        assert_eq!(exes.meta.batch, 10);
        assert_eq!(exes.backend_name(), "native");
        // cached: same Arc comes back
        let again = s.executables("mlp_b10").unwrap();
        assert!(Arc::ptr_eq(&exes, &again));
        // lookup by (model, batch) uses the same key space
        let by_pair = s.executables_for("lstm", 10).unwrap();
        assert_eq!(by_pair.meta.param_count, 3_023);
    }

    #[test]
    fn native_session_rejects_unknown_variants() {
        let s = Session::native().unwrap();
        assert!(s.executables("transformer_b16").is_err());
        assert!(s.executables("nonsense").is_err());
    }
}
