//! PJRT runtime: artifact manifest + compiled executables.
//!
//! `Session` is the convenience entry point used by the coordinator,
//! examples, and benches: open the artifact dir, pick a model variant,
//! get shared (`Arc`) executables for the training world's threads.

pub mod artifact;
pub mod executor;

pub use artifact::{default_artifact_dir, ArtifactError, Manifest,
                   ModelMeta};
pub use executor::{Client, Executable, GradOutput, ModelExecutables,
                   RuntimeError};

use std::path::Path;
use std::sync::Arc;

#[derive(Debug, thiserror::Error)]
pub enum SessionError {
    #[error(transparent)]
    Artifact(#[from] ArtifactError),
    #[error(transparent)]
    Runtime(#[from] RuntimeError),
}

/// Artifact dir + PJRT client + compile cache.
pub struct Session {
    pub manifest: Manifest,
    pub client: Arc<Client>,
    cache: std::sync::Mutex<
        std::collections::BTreeMap<String, Arc<ModelExecutables>>>,
}

impl Session {
    pub fn open(artifact_dir: &Path) -> Result<Session, SessionError> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = Client::cpu()?;
        Ok(Session {
            manifest,
            client,
            cache: std::sync::Mutex::new(Default::default()),
        })
    }

    /// Open the default artifact dir (`$MPI_LEARN_ARTIFACTS` or
    /// `./artifacts`).
    pub fn open_default() -> Result<Session, SessionError> {
        Self::open(&default_artifact_dir())
    }

    /// Compile (or fetch cached) executables for a manifest key like
    /// `lstm_b100`.
    pub fn executables(&self, key: &str)
        -> Result<Arc<ModelExecutables>, SessionError> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exes) = cache.get(key) {
                return Ok(exes.clone());
            }
        }
        let meta = self.manifest.get(key)?.clone();
        let exes = Arc::new(ModelExecutables::load(&self.client, &meta,
                                                   true)?);
        self.cache
            .lock()
            .unwrap()
            .insert(key.to_string(), exes.clone());
        Ok(exes)
    }

    /// Variant lookup by (model, batch).
    pub fn executables_for(&self, model: &str, batch: usize)
        -> Result<Arc<ModelExecutables>, SessionError> {
        let key = self.manifest.variant(model, batch)?.key.clone();
        self.executables(&key)
    }
}
