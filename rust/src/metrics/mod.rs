//! Training metrics: history records, timers, throughput accounting.
//!
//! Everything a finished run hands back or a live service exports:
//!
//! * [`History`] — the observer's record of one training run
//!   (per-epoch losses, [`ValRecord`] validation points, final
//!   [`WorkerReport`]s, wall-clock). Returned by
//!   `Experiment::run` / `driver::train`, serialized by the benches
//!   and the `jsonl` callback.
//! * [`Stopwatch`] — monotonic split timer behind the
//!   `grad_time_s` / `comm_wait_s` accounting in [`WorkerReport`].
//! * [`Histogram`] — mergeable log-bucketed latency histogram
//!   (p50/p99/p999) behind the serving front-end's `GET /metrics`
//!   endpoint; buckets are fixed at compile time so replicas'
//!   histograms merge without negotiation.
//!
//! None of this is wired to a metrics *backend* — export is plain
//! text (serving) or JSONL (training callbacks), in keeping with the
//! crate's no-new-dependencies budget.

use std::time::Instant;

/// One validation round's results.
#[derive(Clone, Debug, PartialEq)]
pub struct ValRecord {
    /// Seconds since training start.
    pub t_s: f64,
    /// Master update count when validation ran.
    pub update: u64,
    pub val_loss: f32,
    pub val_acc: f32,
}

/// One worker's final report.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    pub rank: usize,
    pub epochs: u32,
    pub batches: u64,
    pub samples: u64,
    pub last_train_loss: f32,
    pub grad_time_s: f64,
    pub comm_wait_s: f64,
}

/// Full history of one training run — what benches/examples serialize.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub validations: Vec<ValRecord>,
    pub workers: Vec<WorkerReport>,
    pub master_updates: u64,
    pub master_update_time_s: f64,
    pub master_idle_time_s: f64,
    pub wallclock_s: f64,
    pub train_losses: Vec<(u64, f32)>,
    /// Mean gradient staleness in master updates (the Fig 2 mechanism:
    /// ~W-1 for W async workers).
    pub staleness_mean: f64,
    pub staleness_max: u64,
}

impl History {
    pub fn final_val_acc(&self) -> Option<f32> {
        self.validations.last().map(|v| v.val_acc)
    }

    pub fn best_val_acc(&self) -> Option<f32> {
        self.validations
            .iter()
            .map(|v| v.val_acc)
            .max_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Lowest validation loss seen (what `EarlyStopping` and the
    /// best-only `ModelCheckpoint` track). NaN records are skipped.
    pub fn best_val_loss(&self) -> Option<f32> {
        self.validations
            .iter()
            .map(|v| v.val_loss)
            .filter(|l| l.is_finite())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    pub fn total_samples(&self) -> u64 {
        self.workers.iter().map(|w| w.samples).sum()
    }

    pub fn throughput_samples_per_s(&self) -> f64 {
        if self.wallclock_s > 0.0 {
            self.total_samples() as f64 / self.wallclock_s
        } else {
            0.0
        }
    }

    /// CSV of the validation curve (plots for Fig 2-style output).
    pub fn validations_csv(&self) -> String {
        let mut out = String::from("t_s,update,val_loss,val_acc\n");
        for v in &self.validations {
            out.push_str(&format!("{:.3},{},{:.5},{:.4}\n", v.t_s,
                                  v.update, v.val_loss, v.val_acc));
        }
        out
    }

    /// CSV of the training-loss curve (end-to-end driver logging).
    pub fn train_loss_csv(&self) -> String {
        let mut out = String::from("update,train_loss\n");
        for (u, l) in &self.train_losses {
            out.push_str(&format!("{u},{l:.5}\n"));
        }
        out
    }
}

/// Accumulating stopwatch for hot-path segments.
#[derive(Debug)]
pub struct Stopwatch {
    total: f64,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { total: 0.0, started: None }
    }

    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed().as_secs_f64();
        }
    }

    pub fn total_s(&self) -> f64 {
        self.total
    }

    /// Time one closure and accumulate.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// Fixed log-bucket latency histogram (HDR-style, 16 sub-buckets per
/// octave → worst-case quantile error ~3%), merge-able across threads.
///
/// Values are unsigned integers (the serving path records nanoseconds).
/// `record` is O(1) with no allocation; `merge` folds a per-worker
/// histogram into an aggregate, so each replica/batcher thread can own
/// a private `Histogram` and the `/metrics` endpoint can sum them
/// without contention on the hot path.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

/// 16 sub-buckets per power of two.
const HIST_SUB_BITS: u32 = 4;
const HIST_SUB: usize = 1 << HIST_SUB_BITS;
/// Octaves 4..=63 each contribute HIST_SUB buckets, plus the exact
/// 0..16 range: (63 - 4 + 1) * 16 + 16 = 976.
const HIST_BUCKETS: usize = (64 - HIST_SUB_BITS as usize) * HIST_SUB
    + HIST_SUB;

fn hist_bucket(v: u64) -> usize {
    if v < HIST_SUB as u64 {
        return v as usize; // exact buckets for 0..15
    }
    let msb = 63 - v.leading_zeros(); // >= HIST_SUB_BITS
    let octave = (msb - HIST_SUB_BITS + 1) as usize;
    let sub = ((v >> (msb - HIST_SUB_BITS)) as usize) & (HIST_SUB - 1);
    octave * HIST_SUB + sub
}

/// Midpoint of the value range bucket `idx` covers (its inverse).
fn hist_value(idx: usize) -> u64 {
    if idx < HIST_SUB {
        return idx as u64;
    }
    let octave = (idx / HIST_SUB) as u32;
    let sub = (idx % HIST_SUB) as u64;
    let width = 1u64 << (octave - 1);
    let lower = (HIST_SUB as u64 + sub) << (octave - 1);
    lower + (width - 1) / 2
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; HIST_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[hist_bucket(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold `other` into `self` (exact: bucket-wise addition).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min }
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile in `[0, 1]`: the representative value of the bucket
    /// holding the `ceil(q * count)`-th smallest sample. 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                // Clamp to the true observed extremes so p0/p100 are
                // exact rather than bucket midpoints.
                return hist_value(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Summary as a JSON object — the payload the `/metrics` route and
    /// the JsonlLogger-style periodic dump both serialize.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("min", Json::Num(self.min() as f64)),
            ("max", Json::Num(self.max() as f64)),
            ("mean", Json::Num(self.mean())),
            ("p50", Json::Num(self.p50() as f64)),
            ("p99", Json::Num(self.p99() as f64)),
            ("p999", Json::Num(self.p999() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_accessors() {
        let mut h = History::default();
        assert_eq!(h.final_val_acc(), None);
        h.validations.push(ValRecord { t_s: 1.0, update: 10,
                                       val_loss: 1.0, val_acc: 0.5 });
        h.validations.push(ValRecord { t_s: 2.0, update: 20,
                                       val_loss: 0.8, val_acc: 0.7 });
        h.validations.push(ValRecord { t_s: 3.0, update: 30,
                                       val_loss: 0.9, val_acc: 0.6 });
        assert_eq!(h.final_val_acc(), Some(0.6));
        assert_eq!(h.best_val_acc(), Some(0.7));
        let csv = h.validations_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("t_s,"));
    }

    #[test]
    fn throughput_math() {
        let mut h = History::default();
        h.workers.push(WorkerReport { samples: 500, ..Default::default() });
        h.workers.push(WorkerReport { samples: 300, ..Default::default() });
        h.wallclock_s = 4.0;
        assert_eq!(h.total_samples(), 800);
        assert!((h.throughput_samples_per_s() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_empty_is_all_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.p999(), 0);
    }

    #[test]
    fn histogram_small_values_are_exact() {
        // Values below 32 land in exact buckets, so every quantile
        // matches the sorted-vec order statistic exactly.
        let mut h = Histogram::new();
        let vals: Vec<u64> = (0..32).chain(0..32).collect();
        for &v in &vals {
            h.record(v);
        }
        let mut sorted = vals.clone();
        sorted.sort_unstable();
        for q in [0.01, 0.25, 0.50, 0.75, 0.99, 1.0] {
            let rank = ((q * sorted.len() as f64).ceil() as usize)
                .clamp(1, sorted.len());
            assert_eq!(h.quantile(q), sorted[rank - 1],
                       "q={q} diverged from oracle");
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 31);
        assert_eq!(h.count(), 64);
    }

    #[test]
    fn histogram_matches_sorted_vec_oracle_within_bucket_error() {
        // Log-spaced latencies across six orders of magnitude: the
        // histogram's p50/p99/p999 must track util::stats::percentile
        // on the raw sorted values within the 1/32 bucket resolution
        // (plus oracle interpolation slack).
        use crate::util::rng::Rng;
        use crate::util::stats::percentile;
        let mut rng = Rng::new(42);
        let mut h = Histogram::new();
        let mut raw: Vec<f64> = Vec::new();
        for _ in 0..10_000 {
            // exp-ish spread: 1e2 .. 1e8 ns
            let e = rng.uniform_f32(2.0, 8.0) as f64;
            let v = 10f64.powf(e) as u64;
            h.record(v);
            raw.push(v as f64);
        }
        raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (q, got) in [(50.0, h.p50()), (99.0, h.p99()),
                         (99.9, h.p999())] {
            let want = percentile(&raw, q);
            let rel = (got as f64 - want).abs() / want;
            assert!(rel < 0.05,
                    "q={q}: hist {got} vs oracle {want:.0} (rel {rel:.4})");
        }
    }

    #[test]
    fn histogram_merge_equals_single() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        let vals: Vec<u64> = (0..2_000)
            .map(|_| rng.uniform_f32(1.0, 1e7) as u64)
            .collect();
        let mut whole = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for (i, &v) in vals.iter().enumerate() {
            whole.record(v);
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        assert_eq!(a.p50(), whole.p50());
        assert_eq!(a.p99(), whole.p99());
        assert_eq!(a.p999(), whole.p999());
        assert_eq!(a.mean(), whole.mean());
    }

    #[test]
    fn histogram_json_summary_shape() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        let j = h.to_json();
        let field = |k: &str| j.get(k).unwrap().as_i64().unwrap();
        assert_eq!(field("count"), 3);
        assert_eq!(field("min"), 10);
        assert_eq!(field("max"), 30);
        assert_eq!(field("p50"), 20);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(
            std::time::Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(
            std::time::Duration::from_millis(5)));
        assert!(sw.total_s() >= 0.009, "{}", sw.total_s());
        // stop without start is a no-op
        sw.stop();
    }
}
