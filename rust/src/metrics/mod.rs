//! Training metrics: history records, timers, throughput accounting.

use std::time::Instant;

/// One validation round's results.
#[derive(Clone, Debug, PartialEq)]
pub struct ValRecord {
    /// Seconds since training start.
    pub t_s: f64,
    /// Master update count when validation ran.
    pub update: u64,
    pub val_loss: f32,
    pub val_acc: f32,
}

/// One worker's final report.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    pub rank: usize,
    pub epochs: u32,
    pub batches: u64,
    pub samples: u64,
    pub last_train_loss: f32,
    pub grad_time_s: f64,
    pub comm_wait_s: f64,
}

/// Full history of one training run — what benches/examples serialize.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub validations: Vec<ValRecord>,
    pub workers: Vec<WorkerReport>,
    pub master_updates: u64,
    pub master_update_time_s: f64,
    pub master_idle_time_s: f64,
    pub wallclock_s: f64,
    pub train_losses: Vec<(u64, f32)>,
    /// Mean gradient staleness in master updates (the Fig 2 mechanism:
    /// ~W-1 for W async workers).
    pub staleness_mean: f64,
    pub staleness_max: u64,
}

impl History {
    pub fn final_val_acc(&self) -> Option<f32> {
        self.validations.last().map(|v| v.val_acc)
    }

    pub fn best_val_acc(&self) -> Option<f32> {
        self.validations
            .iter()
            .map(|v| v.val_acc)
            .max_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Lowest validation loss seen (what `EarlyStopping` and the
    /// best-only `ModelCheckpoint` track). NaN records are skipped.
    pub fn best_val_loss(&self) -> Option<f32> {
        self.validations
            .iter()
            .map(|v| v.val_loss)
            .filter(|l| l.is_finite())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    pub fn total_samples(&self) -> u64 {
        self.workers.iter().map(|w| w.samples).sum()
    }

    pub fn throughput_samples_per_s(&self) -> f64 {
        if self.wallclock_s > 0.0 {
            self.total_samples() as f64 / self.wallclock_s
        } else {
            0.0
        }
    }

    /// CSV of the validation curve (plots for Fig 2-style output).
    pub fn validations_csv(&self) -> String {
        let mut out = String::from("t_s,update,val_loss,val_acc\n");
        for v in &self.validations {
            out.push_str(&format!("{:.3},{},{:.5},{:.4}\n", v.t_s,
                                  v.update, v.val_loss, v.val_acc));
        }
        out
    }

    /// CSV of the training-loss curve (end-to-end driver logging).
    pub fn train_loss_csv(&self) -> String {
        let mut out = String::from("update,train_loss\n");
        for (u, l) in &self.train_losses {
            out.push_str(&format!("{u},{l:.5}\n"));
        }
        out
    }
}

/// Accumulating stopwatch for hot-path segments.
#[derive(Debug)]
pub struct Stopwatch {
    total: f64,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { total: 0.0, started: None }
    }

    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed().as_secs_f64();
        }
    }

    pub fn total_s(&self) -> f64 {
        self.total
    }

    /// Time one closure and accumulate.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_accessors() {
        let mut h = History::default();
        assert_eq!(h.final_val_acc(), None);
        h.validations.push(ValRecord { t_s: 1.0, update: 10,
                                       val_loss: 1.0, val_acc: 0.5 });
        h.validations.push(ValRecord { t_s: 2.0, update: 20,
                                       val_loss: 0.8, val_acc: 0.7 });
        h.validations.push(ValRecord { t_s: 3.0, update: 30,
                                       val_loss: 0.9, val_acc: 0.6 });
        assert_eq!(h.final_val_acc(), Some(0.6));
        assert_eq!(h.best_val_acc(), Some(0.7));
        let csv = h.validations_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("t_s,"));
    }

    #[test]
    fn throughput_math() {
        let mut h = History::default();
        h.workers.push(WorkerReport { samples: 500, ..Default::default() });
        h.workers.push(WorkerReport { samples: 300, ..Default::default() });
        h.wallclock_s = 4.0;
        assert_eq!(h.total_samples(), 800);
        assert!((h.throughput_samples_per_s() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(
            std::time::Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(
            std::time::Duration::from_millis(5)));
        assert!(sw.total_s() >= 0.009, "{}", sw.total_s());
        // stop without start is a no-op
        sw.stop();
    }
}
