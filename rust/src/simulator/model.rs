//! Cost model + workload shape for the protocol simulator.

use crate::mpi::codec::Codec;
use crate::util::rng::Rng;

/// Calibrated cost parameters.
///
/// The defaults below correspond to this host's measured CPU-PJRT numbers
/// for the paper LSTM at batch 100 (see EXPERIMENTS.md §Calibration); the
/// benches overwrite them with live measurements before sweeping. The two
/// transport presets mirror the paper's testbeds.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Fixed per-batch gradient overhead (dispatch etc.), seconds.
    pub t_grad_fixed: f64,
    /// Per-sample gradient compute, seconds.
    pub t_grad_per_sample: f64,
    /// Master optimizer update per gradient, seconds.
    pub t_update: f64,
    /// One validation round (serial on the master), seconds.
    pub t_val: f64,
    /// One-way message latency across the *inter-group* link (the
    /// network between nodes), seconds.
    pub latency: f64,
    /// Inter-group link bandwidth, bytes/second.
    pub bandwidth_bytes_per_s: f64,
    /// One-way latency between ranks of the SAME group (node-local:
    /// shared memory / NVLink / loopback), seconds. Flat collectives
    /// never use it; the hierarchical all-reduce pays it on the
    /// intra-group ring phases.
    pub intra_latency: f64,
    /// Intra-group (node-local) bandwidth, bytes/second.
    pub intra_bandwidth_bytes_per_s: f64,
    /// Weight/gradient message size, bytes.
    pub msg_bytes: f64,
    /// Multiplicative gradient-time jitter (0 = deterministic; 0.2 means
    /// +-~20% lognormal-ish spread). Real clusters always have some.
    pub jitter: f64,
    /// Wire bytes per payload byte after compression (1.0 = raw f32;
    /// see [`Codec::wire_ratio`]). Scales the bandwidth term of both
    /// the PS transfer time and the ring all-reduce — latency is
    /// unaffected, which is exactly why compression helps most in the
    /// bandwidth-bound regime.
    pub wire_ratio: f64,
    /// Single-thread GEMM throughput of the rank's compute engine,
    /// GFLOP/s (`runtime::kernels`, 2mkn flops per matmul). Calibrated
    /// from a live [`crate::runtime::kernels::gemm_gflops`] probe in
    /// auto mode; the preset values are plausible defaults for the
    /// closed-form BENCH blocks.
    pub gemm_base_gflops: f64,
    /// Amdahl parallel fraction of the kernel work: the share of a
    /// GEMM that scales with `--threads` (row blocks), the rest being
    /// serial dispatch + cache effects. `gemm_speedup` turns this plus
    /// a thread count into a throughput multiplier.
    pub gemm_parallel_frac: f64,
}

impl CostModel {
    /// Shared-memory single-node preset (the paper's Supermicro server).
    pub fn shared_memory(n_params: usize) -> CostModel {
        CostModel {
            t_grad_fixed: 2.0e-3,
            t_grad_per_sample: 1.2e-4,
            t_update: 2.0e-5,
            t_val: 0.0,
            latency: 2.0e-6,
            bandwidth_bytes_per_s: 2.0e10,
            // one shared-memory node: intra == inter
            intra_latency: 2.0e-6,
            intra_bandwidth_bytes_per_s: 2.0e10,
            msg_bytes: (n_params * 4 + 28) as f64,
            jitter: 0.05,
            wire_ratio: 1.0,
            gemm_base_gflops: 4.0,
            gemm_parallel_frac: 0.95,
        }
    }

    /// Paper-testbed preset: GPU workers + Python/Keras master, derived
    /// from the paper's own numbers rather than this host's runtime.
    ///
    /// Derivation (documented in EXPERIMENTS.md §Fig4):
    /// - "This model takes several hours to train on a node with a
    ///   single GPU": 10 epochs x 9500 batches ≈ 95k batches in ~3h
    ///   → t_grad(batch 100) ≈ 110 ms. A GTX1080 running an LSTM(20) is
    ///   launch-bound, so the cost is mostly *fixed*: we split it as
    ///   95 ms fixed + 0.18 ms/sample, which also reproduces Table I's
    ///   batch-size behaviour (batch 1000 ≈ 2.6x batch 100, not 10x —
    ///   the split is fit to Table I's 3.0x@500 point).
    /// - 30x speedup at 60 workers with the master ~saturated
    ///   → master service time ≈ t_grad/30 ≈ 3.6 ms per gradient
    ///   (Keras optimizer apply + mpi4py (de)serialization in Python).
    pub fn paper_gpu(n_params: usize) -> CostModel {
        CostModel {
            t_grad_fixed: 9.5e-2,
            t_grad_per_sample: 1.8e-4,
            t_update: 3.6e-3,
            t_val: 0.0,
            latency: 2.0e-5,
            bandwidth_bytes_per_s: 6.8e9,
            // co-located GPU workers exchange node-locally
            intra_latency: 2.0e-6,
            intra_bandwidth_bytes_per_s: 2.0e10,
            msg_bytes: (n_params * 4 + 28) as f64,
            jitter: 0.1,
            wire_ratio: 1.0,
            // GPU workers: high base throughput, near-perfect scaling
            gemm_base_gflops: 180.0,
            gemm_parallel_frac: 0.99,
        }
    }

    /// FDR-Infiniband cluster preset (the paper's ALCF Cooley).
    pub fn cluster(n_params: usize) -> CostModel {
        CostModel {
            t_grad_fixed: 2.0e-3,
            t_grad_per_sample: 1.2e-4,
            t_update: 2.0e-5,
            t_val: 0.0,
            latency: 2.0e-5,
            bandwidth_bytes_per_s: 6.8e9, // FDR ~56 Gb/s
            // ranks of one group share a Cooley node (shared memory)
            intra_latency: 2.0e-6,
            intra_bandwidth_bytes_per_s: 2.0e10,
            msg_bytes: (n_params * 4 + 28) as f64,
            jitter: 0.1,
            wire_ratio: 1.0,
            gemm_base_gflops: 4.0,
            gemm_parallel_frac: 0.95,
        }
    }

    /// Apply a wire codec's volume reduction (see [`Codec::wire_ratio`]).
    pub fn with_compression(mut self, codec: Codec) -> CostModel {
        self.wire_ratio = codec.wire_ratio();
        self
    }

    /// Nominal (jitter-free) gradient time for a batch.
    pub fn grad_time_nominal(&self, batch: usize) -> f64 {
        self.t_grad_fixed + batch as f64 * self.t_grad_per_sample
    }

    /// Amdahl throughput multiplier of the kernel pool at `threads`
    /// compute threads: `1 / ((1-f) + f/t)` with `f =
    /// gemm_parallel_frac`. Monotonic in `t`, capped at `1/(1-f)`;
    /// `threads <= 1` is exactly 1.0 (the serial path).
    pub fn gemm_speedup(&self, threads: usize) -> f64 {
        let t = threads.max(1) as f64;
        let f = self.gemm_parallel_frac.clamp(0.0, 1.0);
        1.0 / ((1.0 - f) + f / t)
    }

    /// Modeled GEMM throughput at `threads`, GFLOP/s. A shape below
    /// the kernels' inline cutoff (`MIN_FLOPS_PER_PART` per part —
    /// too small to farm out) runs serially regardless of the pool,
    /// which [`CostModel::gemm_time`] accounts for.
    pub fn gemm_gflops(&self, threads: usize) -> f64 {
        self.gemm_base_gflops * self.gemm_speedup(threads)
    }

    /// Modeled wall time of one `m x k x k x n` GEMM (2mkn flops) at
    /// `threads`. Mirrors the engine's inline cutoff: a matmul whose
    /// flops cannot fill two minimum-size row parts stays on the
    /// serial path, so small shapes see no speedup (and no pool
    /// overhead either).
    pub fn gemm_time(&self, m: usize, k: usize, n: usize,
                     threads: usize) -> f64 {
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let min_flops_per_part =
            crate::runtime::kernels::MIN_FLOPS_PER_PART as f64;
        let t = if flops < 2.0 * min_flops_per_part { 1 } else { threads };
        flops / (self.gemm_gflops(t) * 1e9)
    }

    /// Jittered gradient time draw.
    pub fn grad_time(&self, batch: usize, rng: &mut Rng) -> f64 {
        let nominal = self.grad_time_nominal(batch);
        if self.jitter <= 0.0 {
            return nominal;
        }
        // clamp at +-3 sigma to keep tails physical
        let z = rng.normal().clamp(-3.0, 3.0);
        nominal * (1.0 + self.jitter * z).max(0.05)
    }

    /// One-way transfer time of a weight/gradient message.
    pub fn transfer_time(&self) -> f64 {
        self.latency
            + self.msg_bytes * self.wire_ratio
                / self.bandwidth_bytes_per_s
    }

    /// Wall time of one chunked ring all-reduce over `n` ranks: the
    /// classic 2(n-1) lockstep steps, each moving a 1/n-size chunk —
    /// per-rank payload volume `2(n-1)/n * msg_bytes`, independent of
    /// the world size in the large-n limit, at the price of a latency
    /// term that grows linearly with n.
    pub fn ring_allreduce_time(&self, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2.0 * (n as f64 - 1.0);
        let chunk_bytes = self.msg_bytes * self.wire_ratio / n as f64;
        steps * (self.latency + chunk_bytes / self.bandwidth_bytes_per_s)
    }

    /// Wall time from gradient-start to fully-reduced gradients when
    /// the round is split into `buckets` equal buckets, each launched
    /// as backprop produces it (`Algo::buckets`; DESIGN.md §Layer DAG
    /// & bucketed overlap).
    ///
    /// Bucket i's collective cannot start before its share of the
    /// backward pass has run (`grad * (i+1)/B`), and the wire is
    /// serial, so each bucket starts at `max(wire-so-far, ready)` and
    /// costs a ring all-reduce of a `1/B`-size message. The monolithic
    /// schedule is `grad + ring_allreduce_time(n)`; with one bucket the
    /// two are identical, and bucketing wins exactly when the
    /// per-bucket compute tail (`grad/B`) outweighs the extra lockstep
    /// latency (`2(n-1) * latency`) each additional bucket adds.
    pub fn bucketed_allreduce_time(&self, n: usize, batch: usize,
                                   buckets: usize) -> f64 {
        let grad = self.grad_time_nominal(batch);
        if n <= 1 {
            return grad;
        }
        let b = buckets.max(1);
        let steps = 2.0 * (n as f64 - 1.0);
        let per_bucket = steps
            * (self.latency
                + self.msg_bytes * self.wire_ratio / b as f64 / n as f64
                    / self.bandwidth_bytes_per_s);
        let mut wire = 0.0f64;
        for i in 0..b {
            let ready = grad * (i + 1) as f64 / b as f64;
            wire = wire.max(ready) + per_bucket;
        }
        wire
    }

    /// Wall time of one **hierarchical** all-reduce over `n` ranks in
    /// `groups` groups of `m = ceil(n/groups)` (matching the collective
    /// layer's ring → tree → ring schedule):
    ///
    /// - intra-group ring reduce-scatter: `m-1` steps of a `1/m` chunk
    ///   at *intra* cost;
    /// - gather onto the leader: `m-1` chunk receives, serialized at
    ///   the leader (intra cost);
    /// - leader binary tree, up then down: `2*ceil(log2 groups)` hop
    ///   levels each moving the full message at *inter* cost — the
    ///   `2(G-1)` ring term collapses to a logarithm;
    /// - re-broadcast around the group ring: `m-1` store-and-forward
    ///   hops of the full message at intra cost.
    ///
    /// With one rank per group (`m == 1`) only the tree terms remain
    /// (a pure tree all-reduce); with one group it degenerates to
    /// intra-only ring phases.
    pub fn hierarchical_allreduce_time(&self, n: usize, groups: usize)
        -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let g = groups.clamp(1, n);
        let m = n.div_ceil(g);
        let bytes = self.msg_bytes * self.wire_ratio;
        let intra_chunk_step = self.intra_latency
            + bytes / m as f64 / self.intra_bandwidth_bytes_per_s;
        let intra_full_step = self.intra_latency
            + bytes / self.intra_bandwidth_bytes_per_s;
        let inter_full_step =
            self.latency + bytes / self.bandwidth_bytes_per_s;
        // ceil(log2 g) without float logs (exact at powers of two)
        let depth = usize::BITS - (g - 1).leading_zeros();
        let reduce_scatter = (m as f64 - 1.0) * intra_chunk_step;
        let gather = (m as f64 - 1.0) * intra_chunk_step;
        let tree = 2.0 * depth as f64 * inter_full_step;
        let bcast = (m as f64 - 1.0) * intra_full_step;
        reduce_scatter + gather + tree + bcast
    }

    /// Wall-clock cost of one elastic recovery (a rank dies mid-round
    /// and the survivors re-form the ring — DESIGN.md §Elasticity):
    ///
    /// - detection: the stalled collective runs out the suspicion
    ///   window (`timeout_s`, `--elastic-timeout-ms`);
    /// - membership agreement: suspect → probe → alive → plan, ~3
    ///   one-way hops between rank 0 and the farthest survivor;
    /// - weight re-replication: store-and-forward around the new ring,
    ///   `m-1` full-message hops from the sync root;
    /// - resume barriers: two scalar agreement collectives (epoch and
    ///   round count), latency-only.
    ///
    /// The timeout dominates at realistic settings — the knob trades
    /// false-positive evictions against recovery latency, which is why
    /// the RUNBOOK tells operators to tune it to tail round time, not
    /// to the mean.
    pub fn elastic_recovery_time(&self, survivors: usize,
                                 timeout_s: f64) -> f64 {
        let m = survivors.max(1) as f64;
        let full_step = self.transfer_time();
        let agreement = 3.0 * full_step;
        let rebroadcast = (m - 1.0) * full_step;
        let barriers = 2.0 * 2.0 * (m - 1.0) * self.latency;
        timeout_s + agreement + rebroadcast + barriers
    }

    /// Fraction of an uninterrupted run's throughput retained when
    /// `churn_events` recoveries (each costing
    /// [`CostModel::elastic_recovery_time`]) interrupt a run of
    /// `run_time_s`. The non-elastic alternative retains 0.0 — the job
    /// dies with the first rank.
    pub fn churn_retention(&self, run_time_s: f64, survivors: usize,
                           churn_events: usize, timeout_s: f64) -> f64 {
        if run_time_s <= 0.0 {
            return 0.0;
        }
        let lost = churn_events as f64
            * self.elastic_recovery_time(survivors, timeout_s);
        run_time_s / (run_time_s + lost)
    }
}

/// Workload shape: the paper's protocol (fixed dataset divided evenly,
/// train until each worker has seen its division `epochs` times).
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub n_workers: usize,
    /// Total training samples across all workers (per epoch).
    pub total_samples: u64,
    pub batch: usize,
    pub epochs: u32,
    /// Master validates every N updates (0 = never).
    pub validate_every: u64,
    /// Synchronous barrier mode.
    pub sync: bool,
}

impl SimConfig {
    /// Batches each worker contributes over the whole run.
    pub fn batches_per_worker(&self) -> u64 {
        let per_worker = self.total_samples / self.n_workers as u64;
        (per_worker / self.batch as u64) * self.epochs as u64
    }
}

/// Simulation outputs.
#[derive(Clone, Debug, PartialEq)]
pub struct SimResult {
    pub total_time_s: f64,
    pub master_busy_s: f64,
    pub master_utilization: f64,
    pub updates: u64,
    pub validations: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_per_worker_divides_dataset() {
        let cfg = SimConfig {
            n_workers: 4,
            total_samples: 10_000,
            batch: 100,
            epochs: 10,
            validate_every: 0,
            sync: false,
        };
        assert_eq!(cfg.batches_per_worker(), 25 * 10);
    }

    #[test]
    fn transfer_time_components() {
        let c = CostModel {
            latency: 1e-5,
            bandwidth_bytes_per_s: 1e9,
            msg_bytes: 1e6,
            ..CostModel::shared_memory(100)
        };
        assert!((c.transfer_time() - (1e-5 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn compression_scales_the_bandwidth_term_only() {
        let c = CostModel {
            latency: 1e-5,
            bandwidth_bytes_per_s: 1e9,
            msg_bytes: 1e6,
            ..CostModel::shared_memory(100)
        };
        let half = c.clone().with_compression(Codec::Fp16);
        assert!((half.transfer_time() - (1e-5 + 5e-4)).abs() < 1e-12);
        let sparse = c.clone()
            .with_compression(Codec::TopK { k: 0.1 });
        assert!((sparse.transfer_time() - (1e-5 + 2e-4)).abs() < 1e-12);
        // the ring's bandwidth term halves too; its latency term does
        // not — compression cannot beat the 2(n-1) lockstep floor
        let t_raw = c.ring_allreduce_time(8);
        let t_half = half.ring_allreduce_time(8);
        let floor = 2.0 * 7.0 * c.latency;
        assert!(t_half < t_raw);
        assert!(t_half > floor);
        assert!((t_raw - floor) / (t_half - floor) > 1.99);
        // identity codec is a no-op
        let same = c.clone().with_compression(Codec::Fp32);
        assert_eq!(same.transfer_time(), c.transfer_time());
    }

    #[test]
    fn jitter_zero_is_deterministic() {
        let c = CostModel { jitter: 0.0,
                            ..CostModel::shared_memory(3000) };
        let mut rng = Rng::new(0);
        assert_eq!(c.grad_time(100, &mut rng),
                   c.grad_time_nominal(100));
    }

    #[test]
    fn jitter_stays_positive() {
        let c = CostModel { jitter: 0.5,
                            ..CostModel::shared_memory(3000) };
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            assert!(c.grad_time(100, &mut rng) > 0.0);
        }
    }

    #[test]
    fn ring_time_zero_for_singleton_and_grows_with_latency() {
        let c = CostModel::cluster(3_023);
        assert_eq!(c.ring_allreduce_time(1), 0.0);
        let t2 = c.ring_allreduce_time(2);
        let t8 = c.ring_allreduce_time(8);
        assert!(t2 > 0.0);
        // more ranks -> more lockstep latency terms
        assert!(t8 > t2);
        // but the per-rank payload volume stays bounded: the bandwidth
        // component approaches 2 * msg_bytes / bw
        let bw_only = CostModel { latency: 0.0, ..c };
        let cap = 2.0 * bw_only.msg_bytes / bw_only.bandwidth_bytes_per_s;
        assert!(bw_only.ring_allreduce_time(64) < cap + 1e-12);
    }

    #[test]
    fn bucketed_overlap_beats_serial_compute_then_reduce() {
        // The round's wall clock: bucketed (overlapped) vs monolithic
        // (full backprop, then one standalone reduce). This inequality
        // at n >= 8 is also the CI bench-smoke overlap gate.
        let c = CostModel::cluster(3_023);
        let serial = |n: usize| {
            c.grad_time_nominal(100) + c.ring_allreduce_time(n)
        };
        for n in [8usize, 16, 32, 64] {
            let bucketed = c.bucketed_allreduce_time(n, 100, 4);
            assert!(
                bucketed < serial(n),
                "n={n}: bucketed {bucketed:.3e} !< serial {:.3e}",
                serial(n)
            );
        }
        // one bucket IS the serial schedule (identical latency count)
        let one = c.bucketed_allreduce_time(8, 100, 1);
        assert!((one - serial(8)).abs() < 1e-15);
        // over-bucketing drowns the overlap in lockstep latency terms
        assert!(c.bucketed_allreduce_time(8, 100, 1000)
                    > c.bucketed_allreduce_time(8, 100, 4));
        // singleton world: compute only, no wire at all
        assert_eq!(c.bucketed_allreduce_time(1, 100, 4),
                   c.grad_time_nominal(100));
    }

    #[test]
    fn hierarchical_beats_flat_ring_for_big_worlds() {
        // The tentpole's economics: on the cluster preset (cheap intra
        // hops, expensive inter hops) the grouped schedule must win
        // from n = 16 up — this inequality is also the CI bench gate.
        let c = CostModel::cluster(3_023);
        for n in [16usize, 32, 64, 128] {
            let flat = c.ring_allreduce_time(n);
            let hier = c.hierarchical_allreduce_time(n, n / 4);
            assert!(hier < flat,
                    "n={n}: hier {hier:.2e} !< flat {flat:.2e}");
        }
        // degenerate shapes stay finite and sane
        assert_eq!(c.hierarchical_allreduce_time(1, 1), 0.0);
        assert!(c.hierarchical_allreduce_time(4, 2) > 0.0);
        // group count is clamped into [1, n]
        assert!(c.hierarchical_allreduce_time(4, 99).is_finite());
    }

    #[test]
    fn hierarchical_tree_term_is_logarithmic() {
        // with the group size m fixed at 4, doubling the group count
        // adds exactly one tree level (2 inter hops: up + down)
        let c = CostModel::cluster(3_023);
        let step = c.latency + c.msg_bytes / c.bandwidth_bytes_per_s;
        let t8 = c.hierarchical_allreduce_time(32, 8);
        let t16 = c.hierarchical_allreduce_time(64, 16);
        assert!((t16 - t8 - 2.0 * step).abs() < 1e-12,
                "t16-t8 = {:.3e}, want {:.3e}", t16 - t8, 2.0 * step);
    }

    #[test]
    fn hierarchical_compression_scales_bandwidth_terms_only() {
        let c = CostModel::cluster(3_023);
        let half = c.clone().with_compression(Codec::Fp16);
        let m = 4usize;
        let g = 4usize;
        let n = m * g;
        let t_raw = c.hierarchical_allreduce_time(n, g);
        let t_half = half.hierarchical_allreduce_time(n, g);
        // latency floor: 3(m-1) intra steps + 2*log2(g) inter steps
        let floor = 3.0 * (m as f64 - 1.0) * c.intra_latency
            + 2.0 * 2.0 * c.latency;
        assert!(t_half < t_raw);
        assert!(t_half > floor);
        assert!((t_raw - floor) / (t_half - floor) > 1.99);
    }

    #[test]
    fn elastic_recovery_cost_shape() {
        let c = CostModel::cluster(3_023);
        // the suspicion window dominates at the default 30 s setting
        let t = c.elastic_recovery_time(7, 30.0);
        assert!(t > 30.0 && t < 30.0 + 1.0, "{t}");
        // more survivors -> more re-replication hops
        assert!(c.elastic_recovery_time(15, 0.0)
                    > c.elastic_recovery_time(3, 0.0));
        // a single survivor pays detection + agreement only (no ring)
        let solo = c.elastic_recovery_time(1, 1.0);
        assert!((solo - (1.0 + 3.0 * c.transfer_time())).abs() < 1e-12);
        // retention: churn-free runs keep everything; each event eats
        // one recovery window; the denominator grows monotonically
        assert_eq!(c.churn_retention(100.0, 7, 0, 30.0), 1.0);
        let one = c.churn_retention(3600.0, 7, 1, 30.0);
        let two = c.churn_retention(3600.0, 7, 2, 30.0);
        assert!(one < 1.0 && two < one, "{one} {two}");
        assert!(one > 0.99, "a 30 s recovery in a 1 h run: {one}");
        assert_eq!(c.churn_retention(0.0, 7, 1, 30.0), 0.0);
    }

    #[test]
    fn gemm_compute_term_shape() {
        let c = CostModel::cluster(3_023);
        // serial is the identity; speedup grows monotonically with
        // threads and stays under the Amdahl cap 1/(1-f)
        assert_eq!(c.gemm_speedup(0), 1.0);
        assert_eq!(c.gemm_speedup(1), 1.0);
        let s2 = c.gemm_speedup(2);
        let s4 = c.gemm_speedup(4);
        let s64 = c.gemm_speedup(64);
        assert!(s2 > 1.0 && s4 > s2 && s64 > s4, "{s2} {s4} {s64}");
        assert!(s64 < 1.0 / (1.0 - c.gemm_parallel_frac) + 1e-9);
        // throughput scales with the speedup
        assert!((c.gemm_gflops(4)
                     - c.gemm_base_gflops * s4).abs() < 1e-9);
        // a large GEMM gets faster with threads...
        let big1 = c.gemm_time(100, 480, 64, 1);
        let big4 = c.gemm_time(100, 480, 64, 4);
        assert!(big4 < big1, "{big4} !< {big1}");
        assert!((big1 / big4 - s4).abs() < 1e-9);
        // ...but a shape under the inline cutoff runs serially at any
        // thread count (the engine never farms it out)
        assert_eq!(c.gemm_time(8, 8, 8, 4), c.gemm_time(8, 8, 8, 1));
        // all presets carry a sane compute term
        for m in [CostModel::shared_memory(100),
                  CostModel::paper_gpu(100), CostModel::cluster(100)] {
            assert!(m.gemm_base_gflops > 0.0);
            assert!((0.0..1.0).contains(&m.gemm_parallel_frac));
        }
    }

    #[test]
    fn presets_differ_in_latency() {
        let s = CostModel::shared_memory(3000);
        let c = CostModel::cluster(3000);
        assert!(c.latency > s.latency);
        assert!(c.bandwidth_bytes_per_s < s.bandwidth_bytes_per_s);
    }
}
