//! Discrete-event simulator of the Downpour protocol — the cluster-scale
//! substitute (DESIGN.md §Substitutions).
//!
//! Figures 3/4 and Table I of the paper measure *protocol-level* time: how
//! long until every worker has processed its division of the data E times,
//! given that the master serializes weight updates (and validation). That
//! is exactly what this simulator computes, using *measured* per-batch
//! gradient cost, per-update master cost, and per-byte transfer cost from
//! the real runtime (see `benches/fig4_cluster_speedup.rs` for the
//! calibration pass). It reproduces the linear regime, the master-bound
//! saturation (~30x at 60 workers), and the batch-size trade-off of
//! Table I without 60 physical GPUs.

pub mod calibrate;
pub mod model;

pub use calibrate::{measure_costs, median_and_spread, Calibration,
                    LinkCalibration, LinkCost};
pub use model::{CostModel, SimConfig, SimResult};

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::util::rng::Rng;

/// One pending gradient arrival at the master.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Arrival {
    time: f64,
    worker: usize,
}

impl Eq for Arrival {}

impl Ord for Arrival {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by time (BinaryHeap is a max-heap)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.worker.cmp(&self.worker))
    }
}

impl PartialOrd for Arrival {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulate one asynchronous Downpour run; see [`CostModel`] for the cost
/// parameters and [`SimConfig`] for the workload shape.
pub fn simulate_async(cost: &CostModel, cfg: &SimConfig, seed: u64)
    -> SimResult {
    let batches_per_worker = cfg.batches_per_worker();
    let mut remaining: Vec<u64> =
        vec![batches_per_worker; cfg.n_workers];
    let mut rng = Rng::new(seed);
    let mut heap = BinaryHeap::new();
    let xfer = cost.transfer_time();

    for w in 0..cfg.n_workers {
        if remaining[w] > 0 {
            let t = cost.grad_time(cfg.batch, &mut rng) + xfer;
            heap.push(Arrival { time: t, worker: w });
        }
    }

    let mut master_free = 0.0f64;
    let mut master_busy = 0.0f64;
    let mut updates = 0u64;
    let mut validations = 0u64;
    let mut finish = 0.0f64;

    while let Some(Arrival { time, worker }) = heap.pop() {
        let start = master_free.max(time);
        let done = start + cost.t_update;
        master_busy += cost.t_update;
        master_free = done;
        updates += 1;
        if cfg.validate_every > 0 && updates % cfg.validate_every == 0 {
            master_free += cost.t_val;
            master_busy += cost.t_val;
            validations += 1;
        }
        // weights travel back; worker either starts its next batch or is
        // finished once it has its final weights in hand
        let back_at = done + xfer;
        remaining[worker] -= 1;
        if remaining[worker] > 0 {
            let next = back_at + cost.grad_time(cfg.batch, &mut rng)
                + xfer;
            heap.push(Arrival { time: next, worker });
        } else {
            finish = finish.max(back_at);
        }
    }

    // the run ends when the last worker holds its final weights AND the
    // master has drained any trailing validation work
    let total = finish.max(master_free);
    SimResult {
        total_time_s: total,
        master_busy_s: master_busy,
        master_utilization: if total > 0.0 { master_busy / total }
                            else { 0.0 },
        updates,
        validations,
    }
}

/// Simulate one synchronous run (barrier per round).
pub fn simulate_sync(cost: &CostModel, cfg: &SimConfig, seed: u64)
    -> SimResult {
    let rounds = cfg.batches_per_worker();
    let mut rng = Rng::new(seed);
    let xfer = cost.transfer_time();
    let mut t = 0.0f64;
    let mut master_busy = 0.0f64;
    let mut validations = 0u64;
    for round in 0..rounds {
        // slowest worker gates the barrier
        let slowest = (0..cfg.n_workers)
            .map(|_| cost.grad_time(cfg.batch, &mut rng))
            .fold(0.0f64, f64::max);
        t += slowest + xfer + cost.t_update + xfer;
        master_busy += cost.t_update;
        if cfg.validate_every > 0
            && (round + 1) % cfg.validate_every == 0 {
            t += cost.t_val;
            master_busy += cost.t_val;
            validations += 1;
        }
    }
    SimResult {
        total_time_s: t,
        master_busy_s: master_busy,
        master_utilization: if t > 0.0 { master_busy / t } else { 0.0 },
        updates: rounds,
        validations,
    }
}

pub fn simulate(cost: &CostModel, cfg: &SimConfig, seed: u64)
    -> SimResult {
    if cfg.sync {
        simulate_sync(cost, cfg, seed)
    } else {
        simulate_async(cost, cfg, seed)
    }
}

/// Shared protocol loop of the masterless modes: per round, the
/// slowest rank's gradient gates the lockstep collective (wall time
/// `collective_s`, whatever its topology), then every rank applies the
/// identical update in parallel. Rank 0's validation still serializes
/// the world (it is a barrier participant), but there is no
/// per-gradient master service time — the quantity whose saturation
/// caps the parameter-server curves of Figs 3/4. One implementation so
/// flat-ring and hierarchical simulations can never diverge in
/// anything but the collective term.
fn simulate_masterless(cost: &CostModel, cfg: &SimConfig,
                       collective_s: f64, seed: u64) -> SimResult {
    let rounds = cfg.batches_per_worker();
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut rank0_busy = 0.0f64;
    let mut validations = 0u64;
    for round in 0..rounds {
        let slowest = (0..cfg.n_workers)
            .map(|_| cost.grad_time(cfg.batch, &mut rng))
            .fold(0.0f64, f64::max);
        t += slowest + collective_s + cost.t_update;
        rank0_busy += cost.t_update;
        if cfg.validate_every > 0
            && (round + 1) % cfg.validate_every == 0 {
            t += cost.t_val;
            rank0_busy += cost.t_val;
            validations += 1;
        }
    }
    SimResult {
        total_time_s: t,
        master_busy_s: rank0_busy,
        master_utilization: if t > 0.0 { rank0_busy / t } else { 0.0 },
        updates: rounds,
        validations,
    }
}

/// Simulate one masterless flat-ring all-reduce run
/// (`Mode::AllReduce`); see [`simulate_masterless`] for the protocol.
pub fn simulate_allreduce(cost: &CostModel, cfg: &SimConfig, seed: u64)
    -> SimResult {
    simulate_masterless(cost, cfg,
                        cost.ring_allreduce_time(cfg.n_workers), seed)
}

/// Simulate one masterless **hierarchical** all-reduce run
/// (`Mode::AllReduce` + hierarchy): identical protocol to
/// [`simulate_allreduce`], but the per-round collective is the grouped
/// ring → tree → ring schedule
/// ([`CostModel::hierarchical_allreduce_time`]) — the flat ring's
/// `2(n-1)` inter-node latency term becomes `2(m-1)` cheap intra-group
/// steps plus `O(log groups)` inter-group tree levels.
pub fn simulate_hier_allreduce(cost: &CostModel, cfg: &SimConfig,
                               groups: usize, seed: u64) -> SimResult {
    simulate_masterless(
        cost, cfg,
        cost.hierarchical_allreduce_time(cfg.n_workers, groups), seed)
}

/// Speedup-vs-workers series for the hierarchical all-reduce
/// (`groups` fixed across the sweep; each world splits into `groups`
/// equal groups, clamped to the world size).
pub fn speedup_curve_hier_allreduce(cost: &CostModel, base: &SimConfig,
                                    worker_counts: &[usize],
                                    groups: usize, seed: u64)
    -> Vec<(usize, f64)> {
    let t1 = simulate_hier_allreduce(
        cost, &SimConfig { n_workers: 1, ..base.clone() }, groups, seed)
        .total_time_s;
    worker_counts
        .iter()
        .map(|&w| {
            let cfg = SimConfig { n_workers: w, ..base.clone() };
            let t = simulate_hier_allreduce(cost, &cfg, groups,
                                            seed ^ w as u64)
                .total_time_s;
            (w, t1 / t)
        })
        .collect()
}

/// Speedup-vs-workers series for the all-reduce mode (fixed total
/// dataset divided evenly, relative to one worker) — the masterless
/// counterpart of [`speedup_curve`] for Fig-3/4-style comparisons.
pub fn speedup_curve_allreduce(cost: &CostModel, base: &SimConfig,
                               worker_counts: &[usize], seed: u64)
    -> Vec<(usize, f64)> {
    let t1 = simulate_allreduce(
        cost, &SimConfig { n_workers: 1, ..base.clone() }, seed)
        .total_time_s;
    worker_counts
        .iter()
        .map(|&w| {
            let cfg = SimConfig { n_workers: w, ..base.clone() };
            let t = simulate_allreduce(cost, &cfg, seed ^ w as u64)
                .total_time_s;
            (w, t1 / t)
        })
        .collect()
}

/// Speedup-vs-workers series: fixed total dataset divided evenly (the
/// paper's Figs 3/4 protocol), speedup relative to one worker.
pub fn speedup_curve(cost: &CostModel, base: &SimConfig,
                     worker_counts: &[usize], seed: u64)
    -> Vec<(usize, f64)> {
    let t1 = simulate(cost,
                      &SimConfig { n_workers: 1, ..base.clone() },
                      seed)
        .total_time_s;
    worker_counts
        .iter()
        .map(|&w| {
            let cfg = SimConfig {
                n_workers: w,
                total_samples: base.total_samples,
                ..base.clone()
            };
            let t = simulate(cost, &cfg, seed ^ w as u64).total_time_s;
            (w, t1 / t)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> CostModel {
        CostModel {
            t_grad_fixed: 2e-3,
            t_grad_per_sample: 1e-4,
            t_update: 5e-4,
            t_val: 0.0,
            latency: 1e-5,
            bandwidth_bytes_per_s: 5e9,
            intra_latency: 1e-6,
            intra_bandwidth_bytes_per_s: 2e10,
            msg_bytes: 13_000.0,
            jitter: 0.0,
            wire_ratio: 1.0,
        }
    }

    fn cfg(workers: usize) -> SimConfig {
        SimConfig {
            n_workers: workers,
            total_samples: 100_000,
            batch: 100,
            epochs: 1,
            validate_every: 0,
            sync: false,
        }
    }

    #[test]
    fn single_worker_time_is_serial_sum() {
        let c = cost();
        let r = simulate_async(&c, &cfg(1), 0);
        // 1000 batches, each: grad + xfer + update + xfer
        let per = c.grad_time_nominal(100) + 2.0 * c.transfer_time()
            + c.t_update;
        assert!((r.total_time_s - 1000.0 * per).abs() / r.total_time_s
                < 1e-9);
        assert_eq!(r.updates, 1000);
    }

    #[test]
    fn low_worker_counts_scale_linearly() {
        let c = cost();
        let curve = speedup_curve(&c, &cfg(1), &[2, 4, 8], 0);
        for (w, s) in curve {
            assert!(s > 0.85 * w as f64,
                    "speedup {s:.2} at {w} workers too low");
            assert!(s <= w as f64 + 1e-6);
        }
    }

    #[test]
    fn saturation_bounded_by_master_service_rate() {
        let c = cost();
        // with many workers the throughput cap is 1/t_update updates/s
        let r = simulate_async(&c, &cfg(200), 0);
        let cap = r.updates as f64 * c.t_update;
        assert!(r.total_time_s > 0.95 * cap);
        assert!(r.master_utilization > 0.9);
    }

    #[test]
    fn validation_adds_serial_time() {
        let c_no = cost();
        let mut c_val = cost();
        c_val.t_val = 0.05;
        let mut k = cfg(8);
        k.validate_every = 50;
        let t_no = simulate_async(&c_no, &k, 0).total_time_s;
        let r = simulate_async(&c_val, &k, 0);
        assert!(r.validations > 0);
        assert!(r.total_time_s > t_no + 0.8 * r.validations as f64 * 0.05);
    }

    #[test]
    fn bigger_batches_speed_up_fixed_dataset() {
        // Table I mechanism: fewer updates per epoch -> less master
        // serialization at high worker counts.
        let c = cost();
        let mut k = cfg(20);
        k.total_samples = 200_000;
        let t_small = simulate_async(&c, &SimConfig { batch: 10,
            ..k.clone() }, 0).total_time_s;
        let t_mid = simulate_async(&c, &SimConfig { batch: 100,
            ..k.clone() }, 0).total_time_s;
        let t_big = simulate_async(&c, &SimConfig { batch: 1000,
            ..k.clone() }, 0).total_time_s;
        assert!(t_small > t_mid && t_mid > t_big,
                "{t_small} {t_mid} {t_big}");
    }

    #[test]
    fn sync_slower_than_async_with_jitter() {
        let mut c = cost();
        c.jitter = 0.3;
        let k = cfg(16);
        let a = simulate_async(&c, &k, 1).total_time_s;
        let s = simulate_sync(&c, &k, 1).total_time_s;
        assert!(s > a, "sync {s} should exceed async {a} under jitter");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut c = cost();
        c.jitter = 0.2;
        let k = cfg(8);
        assert_eq!(simulate_async(&c, &k, 7).total_time_s,
                   simulate_async(&c, &k, 7).total_time_s);
    }

    #[test]
    fn allreduce_round_count_matches_protocol() {
        let c = cost();
        let k = cfg(8);
        let r = simulate_allreduce(&c, &k, 0);
        assert_eq!(r.updates, k.batches_per_worker());
        assert!(r.total_time_s > 0.0);
        assert!(r.master_busy_s <= r.total_time_s);
    }

    #[test]
    fn allreduce_escapes_master_saturation() {
        // The Fig-3/4 mechanism in reverse: with a costly master update
        // (the paper's Python/Keras master, ~3.6 ms/gradient), async
        // Downpour saturates at t_update per gradient while the ring
        // pays it once per ROUND — so at high worker counts the
        // masterless mode must win by a wide margin.
        let mut c = cost();
        c.t_update = 3.6e-3;
        c.jitter = 0.0;
        let k = SimConfig { total_samples: 600_000, ..cfg(60) };
        let ps = simulate_async(&c, &k, 1).total_time_s;
        let ring = simulate_allreduce(&c, &k, 1).total_time_s;
        assert!(
            ring < ps / 2.0,
            "ring {ring:.2}s should beat saturated PS {ps:.2}s"
        );
    }

    #[test]
    fn hier_allreduce_beats_flat_ring_at_scale() {
        // ISSUE 4 acceptance: under the default (cluster) cost model
        // the hierarchical collective must beat the flat ring for
        // n >= 16 — the 2(n-1) inter-node latency term is the flat
        // ring's scaling wall.
        let c = CostModel::cluster(3_023);
        let mut k = cfg(16);
        k.total_samples = 160_000;
        for n in [16usize, 32, 64] {
            let mut k = SimConfig { n_workers: n, ..k.clone() };
            k.total_samples = 10_000 * n as u64;
            let flat = simulate_allreduce(&c, &k, 3).total_time_s;
            let hier =
                simulate_hier_allreduce(&c, &k, n / 4, 3).total_time_s;
            assert!(hier <= flat,
                    "n={n}: hier {hier:.4}s !<= flat {flat:.4}s");
        }
    }

    #[test]
    fn hier_allreduce_round_count_matches_protocol() {
        let c = cost();
        let k = cfg(8);
        let r = simulate_hier_allreduce(&c, &k, 2, 0);
        assert_eq!(r.updates, k.batches_per_worker());
        assert!(r.total_time_s > 0.0);
        // same protocol, same jitter draws: only the collective term
        // differs from the flat ring
        let flat = simulate_allreduce(&c, &k, 0);
        let per_round_delta = (flat.total_time_s - r.total_time_s)
            / r.updates as f64;
        let want = c.ring_allreduce_time(8)
            - c.hierarchical_allreduce_time(8, 2);
        assert!((per_round_delta - want).abs() < 1e-9);
    }

    #[test]
    fn allreduce_scales_near_linearly_at_low_latency() {
        let mut c = cost();
        c.jitter = 0.0;
        let base = SimConfig { total_samples: 240_000, ..cfg(1) };
        let curve = speedup_curve_allreduce(&c, &base, &[2, 4, 8], 0);
        for (w, s) in curve {
            assert!(s > 0.8 * w as f64,
                    "allreduce speedup {s:.2} at {w} workers too low");
            assert!(s <= w as f64 + 1e-6);
        }
    }
}
