//! Live calibration: measure this host's real protocol costs and inject
//! them into a [`CostModel`]. Used by the Fig 3/4 and Table I benches so
//! simulated sweeps rest on measured numbers (DESIGN.md §Substitutions),
//! and since the self-tuning planner (DESIGN.md §Autotuning) also by the
//! `--auto` startup probe: compute costs come from [`measure_costs`],
//! link costs from the planner's ping-pong probe via [`LinkCost`] /
//! [`LinkCalibration`].

use std::time::Instant;

use crate::optim::OptimizerConfig;
use crate::runtime::ModelExecutables;
use crate::simulator::CostModel;
use crate::tensor::ParamSet;
use crate::util::rng::Rng;

/// Measured per-operation costs.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Median gradient-step time at the measured batch size, seconds.
    pub t_grad: f64,
    /// The batch size it was measured at.
    pub batch: usize,
    /// Median master optimizer update, seconds.
    pub t_update: f64,
    /// Median validation-batch eval time, seconds.
    pub t_eval_batch: f64,
    /// Relative standard deviation of the per-rep gradient timings
    /// (stddev / median). The online re-tuner compares measured-vs-
    /// predicted divergence against this noise floor so a jittery host
    /// is not mistaken for a mis-planned topology.
    pub grad_rel_spread: f64,
    /// Measured single-thread GEMM throughput of the compute engine,
    /// GFLOP/s (the calibration shape of
    /// [`crate::runtime::kernels::gemm_gflops`]).
    pub gemm_gflops_t1: f64,
    /// The same probe on the executables' actual kernel pool.
    pub gemm_gflops_pool: f64,
    /// Thread count of the pool `gemm_gflops_pool` was measured on.
    pub pool_threads: usize,
}

/// Median and relative spread (stddev / median) of a sample set.
///
/// The median discards warm-up stragglers and GC/scheduler outliers
/// that used to drag the old mean-of-reps estimate (a single 10x
/// outlier in 15 reps shifted the mean by ~60%); the spread is returned
/// so callers can tell measurement noise from real model divergence.
pub fn median_and_spread(samples: &[f64]) -> (f64, f64) {
    assert!(!samples.is_empty(), "median of zero samples");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = sorted.len() / 2;
    let median = if sorted.len() % 2 == 1 {
        sorted[mid]
    } else {
        0.5 * (sorted[mid - 1] + sorted[mid])
    };
    if sorted.len() < 2 || median <= 0.0 {
        return (median, 0.0);
    }
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / (sorted.len() - 1) as f64;
    (median, var.sqrt() / median)
}

/// Measure gradient, update, and eval costs for one artifact variant.
///
/// Each rep is timed individually and the **median** is reported
/// (pre-PR 9 this averaged one aggregate wall-clock over all reps after
/// a single warm-up step, so one descheduled rep polluted the whole
/// estimate); the relative spread rides along in
/// [`Calibration::grad_rel_spread`].
pub fn measure_costs(exes: &ModelExecutables, opt: &OptimizerConfig,
                     reps: usize) -> Calibration {
    let reps = reps.max(1);
    let meta = &exes.meta;
    let mut rng = Rng::new(0xCA11B);
    let params = exes.init_params(&mut rng);
    let x: Vec<f32> = (0..meta.x_len())
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let y: Vec<i32> = (0..meta.batch)
        .map(|_| rng.usize_below(meta.classes) as i32)
        .collect();

    // two warm-up steps: the first pays allocator/page-fault costs, the
    // second settles the caches
    exes.grad_step(&params, &x, &y).expect("calibration grad");
    exes.grad_step(&params, &x, &y).expect("calibration grad");
    let grad_samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            exes.grad_step(&params, &x, &y).expect("calibration grad");
            t0.elapsed().as_secs_f64()
        })
        .collect();
    let (t_grad, grad_rel_spread) = median_and_spread(&grad_samples);

    exes.eval_step(&params, &x, &y).expect("calibration eval");
    let eval_samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            exes.eval_step(&params, &x, &y).expect("calibration eval");
            t0.elapsed().as_secs_f64()
        })
        .collect();
    let (t_eval_batch, _) = median_and_spread(&eval_samples);

    // single updates are sub-microsecond — time CHUNKS of updates and
    // take the median chunk mean, which keeps the outlier rejection
    // without asking the clock for nanosecond resolution
    let mut optimizer = opt.build(meta.param_count);
    let mut w = ParamSet::zeros(&meta.params);
    let g = vec![1e-3f32; meta.param_count];
    let chunks = 8usize;
    let per_chunk = 125usize;
    let update_samples: Vec<f64> = (0..chunks)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..per_chunk {
                optimizer.update(w.flat_mut(), &g);
            }
            t0.elapsed().as_secs_f64() / per_chunk as f64
        })
        .collect();
    let (t_update, _) = median_and_spread(&update_samples);

    // GEMM throughput probe, serial vs the executables' actual pool:
    // the two points pin the cost model's Amdahl compute term (see
    // `Calibration::apply`). The shape matches the LSTM backward's
    // dominant matmul, comfortably above the kernels' inline cutoff.
    let serial = crate::util::threadpool::ThreadPool::new(1);
    let gemm_gflops_t1 =
        crate::runtime::kernels::gemm_gflops(&serial, 100, 480, 64, 3);
    let pool = exes.thread_pool();
    let pool_threads = pool.threads();
    let gemm_gflops_pool = if pool_threads > 1 {
        crate::runtime::kernels::gemm_gflops(&pool, 100, 480, 64, 3)
    } else {
        gemm_gflops_t1
    };

    Calibration { t_grad, batch: meta.batch, t_update, t_eval_batch,
                  grad_rel_spread, gemm_gflops_t1, gemm_gflops_pool,
                  pool_threads }
}

impl Calibration {
    /// Project the gradient time to another batch size, splitting the
    /// measured cost into a fixed dispatch part and a per-sample part.
    /// The fixed fraction is itself measured when a batch-10 artifact is
    /// available (see `apply_with_small_batch`); this fallback assumes
    /// 15% fixed, which matches the measured LSTM dispatch share.
    pub fn apply(&self, cost: &mut CostModel) {
        let fixed = 0.15 * self.t_grad;
        cost.t_grad_fixed = fixed;
        cost.t_grad_per_sample = (self.t_grad - fixed)
            / self.batch as f64;
        cost.t_update = self.t_update;
        cost.t_val = 0.0;
        self.apply_gemm(cost);
    }

    /// Inject the measured GEMM throughput: the serial probe becomes
    /// the base, and when the pool probe ran on >= 2 threads the two
    /// points solve the Amdahl parallel fraction exactly
    /// (`s = 1/((1-f) + f/t)` → `f = (1 - 1/s) / (1 - 1/t)`). A
    /// 1-thread pool carries no scaling information, so the preset's
    /// fraction is kept.
    pub fn apply_gemm(&self, cost: &mut CostModel) {
        if self.gemm_gflops_t1 <= 0.0 {
            return;
        }
        cost.gemm_base_gflops = self.gemm_gflops_t1;
        if self.pool_threads > 1 && self.gemm_gflops_pool > 0.0 {
            let s = (self.gemm_gflops_pool / self.gemm_gflops_t1)
                .max(1.0);
            let t = self.pool_threads as f64;
            let f = (1.0 - 1.0 / s) / (1.0 - 1.0 / t);
            cost.gemm_parallel_frac = f.clamp(0.0, 0.999);
        }
    }

    /// Two-point calibration from a second, smaller-batch measurement:
    /// solves t(b) = fixed + b * per_sample exactly.
    pub fn apply_with_small_batch(&self, small: &Calibration,
                                  cost: &mut CostModel) {
        let db = (self.batch - small.batch) as f64;
        let per_sample = ((self.t_grad - small.t_grad) / db).max(1e-9);
        let fixed = (small.t_grad
            - small.batch as f64 * per_sample).max(0.0);
        cost.t_grad_fixed = fixed;
        cost.t_grad_per_sample = per_sample;
        cost.t_update = self.t_update;
    }
}

/// One probed link class (intra-group or inter-group), as measured by
/// the planner's `ProbePing`/`ProbePong` exchange over the real `Comm`
/// layer: empty-payload ping-pongs give the latency, ramped-size float
/// payloads give the bandwidth, and the relative spread of the
/// round-trip samples rides along for the re-tuner's noise floor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkCost {
    /// One-way message latency, seconds.
    pub latency_s: f64,
    /// Sustained bandwidth, bytes/second.
    pub bandwidth_bytes_per_s: f64,
    /// Relative standard deviation of the round-trip samples.
    pub rel_spread: f64,
}

impl LinkCost {
    /// A link that was never probed (degenerate worlds): zero latency,
    /// effectively infinite bandwidth — the sweep then reduces to the
    /// compute terms, which is the right answer for a 1-rank world.
    pub fn unprobed() -> LinkCost {
        LinkCost { latency_s: 0.0, bandwidth_bytes_per_s: f64::MAX,
                   rel_spread: 0.0 }
    }
}

/// The probe phase's full result: both link classes, ready to inject
/// into a [`CostModel`] next to [`Calibration`]'s compute terms.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkCalibration {
    /// The node-local (same provisional group) link class.
    pub intra: LinkCost,
    /// The cross-group link class.
    pub inter: LinkCost,
}

impl LinkCalibration {
    /// Inject the probed link costs into a cost model, replacing the
    /// preset's guessed latency/bandwidth for both link classes.
    pub fn apply(&self, cost: &mut CostModel) {
        cost.latency = self.inter.latency_s;
        cost.bandwidth_bytes_per_s = self.inter.bandwidth_bytes_per_s;
        cost.intra_latency = self.intra.latency_s;
        cost.intra_bandwidth_bytes_per_s =
            self.intra.bandwidth_bytes_per_s;
    }

    /// The noisier of the two link classes' relative spreads — the
    /// re-tuner's divergence test must clear at least this.
    pub fn rel_spread(&self) -> f64 {
        self.intra.rel_spread.max(self.inter.rel_spread)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_discards_the_outlier_the_old_mean_kept() {
        // 14 quiet reps + one 10x straggler: the mean moves ~60%, the
        // median does not move at all — this is the measure_costs bugfix.
        let mut samples = vec![1.0e-3; 14];
        samples.push(1.0e-2);
        let (median, spread) = median_and_spread(&samples);
        assert_eq!(median, 1.0e-3);
        assert!(spread > 0.0);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!(mean > 1.5e-3, "the old estimator was off: {mean}");
    }

    #[test]
    fn median_handles_even_odd_and_degenerate_sets() {
        assert_eq!(median_and_spread(&[2.0]), (2.0, 0.0));
        let (m, s) = median_and_spread(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert!(s > 0.0);
        let (m, _) = median_and_spread(&[5.0, 1.0, 3.0]);
        assert_eq!(m, 3.0, "median sorts first");
        // identical samples: zero spread
        let (m, s) = median_and_spread(&[4.0, 4.0, 4.0, 4.0]);
        assert_eq!((m, s), (4.0, 0.0));
    }

    #[test]
    fn link_calibration_overwrites_the_preset_links() {
        let mut cost = CostModel::cluster(3_023);
        let links = LinkCalibration {
            intra: LinkCost { latency_s: 3.0e-6,
                              bandwidth_bytes_per_s: 1.5e10,
                              rel_spread: 0.02 },
            inter: LinkCost { latency_s: 4.0e-5,
                              bandwidth_bytes_per_s: 5.0e9,
                              rel_spread: 0.08 },
        };
        links.apply(&mut cost);
        assert_eq!(cost.latency, 4.0e-5);
        assert_eq!(cost.bandwidth_bytes_per_s, 5.0e9);
        assert_eq!(cost.intra_latency, 3.0e-6);
        assert_eq!(cost.intra_bandwidth_bytes_per_s, 1.5e10);
        assert_eq!(links.rel_spread(), 0.08);
        // compute terms are untouched — those belong to Calibration
        assert_eq!(cost.t_grad_fixed,
                   CostModel::cluster(3_023).t_grad_fixed);
    }

    #[test]
    fn calibration_apply_splits_fixed_and_per_sample() {
        let cal = Calibration { t_grad: 1.0e-2, batch: 100,
                                t_update: 2.0e-5, t_eval_batch: 5.0e-3,
                                grad_rel_spread: 0.01,
                                gemm_gflops_t1: 2.0,
                                gemm_gflops_pool: 6.0,
                                pool_threads: 4 };
        let mut cost = CostModel::cluster(3_023);
        cal.apply(&mut cost);
        assert!((cost.t_grad_fixed - 1.5e-3).abs() < 1e-15);
        assert!((cost.t_grad_per_sample - 8.5e-5).abs() < 1e-15);
        // the projected time at the measured batch reproduces t_grad
        assert!((cost.grad_time_nominal(100) - cal.t_grad).abs()
                    < 1e-12);
    }

    #[test]
    fn gemm_calibration_solves_the_amdahl_fraction() {
        // a measured 3x speedup on 4 threads: f = (1-1/3)/(1-1/4) = 8/9
        let cal = Calibration { t_grad: 1.0e-2, batch: 100,
                                t_update: 2.0e-5, t_eval_batch: 5.0e-3,
                                grad_rel_spread: 0.01,
                                gemm_gflops_t1: 2.0,
                                gemm_gflops_pool: 6.0,
                                pool_threads: 4 };
        let mut cost = CostModel::cluster(3_023);
        cal.apply(&mut cost);
        assert_eq!(cost.gemm_base_gflops, 2.0);
        assert!((cost.gemm_parallel_frac - 8.0 / 9.0).abs() < 1e-12);
        // the model reproduces the measured point exactly
        assert!((cost.gemm_gflops(4) - 6.0).abs() < 1e-9);
        // a serial pool keeps the preset's fraction (no information)
        let mut cost = CostModel::cluster(3_023);
        let preset_frac = cost.gemm_parallel_frac;
        let serial = Calibration { gemm_gflops_pool: 2.0,
                                   pool_threads: 1, ..cal };
        serial.apply(&mut cost);
        assert_eq!(cost.gemm_base_gflops, 2.0);
        assert_eq!(cost.gemm_parallel_frac, preset_frac);
        // an unmeasured probe (0.0) leaves the whole term alone
        let mut cost = CostModel::cluster(3_023);
        let none = Calibration { gemm_gflops_t1: 0.0, ..cal };
        none.apply_gemm(&mut cost);
        assert_eq!(cost.gemm_base_gflops,
                   CostModel::cluster(3_023).gemm_base_gflops);
    }
}
