//! Live calibration: measure this host's real protocol costs and inject
//! them into a [`CostModel`]. Used by the Fig 3/4 and Table I benches so
//! simulated sweeps rest on measured numbers (DESIGN.md §Substitutions).

use std::time::Instant;

use crate::optim::OptimizerConfig;
use crate::runtime::ModelExecutables;
use crate::simulator::CostModel;
use crate::tensor::ParamSet;
use crate::util::rng::Rng;

/// Measured per-operation costs.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Mean gradient-step time at the measured batch size, seconds.
    pub t_grad: f64,
    /// The batch size it was measured at.
    pub batch: usize,
    /// Mean master optimizer update, seconds.
    pub t_update: f64,
    /// Mean validation-batch eval time, seconds.
    pub t_eval_batch: f64,
}

/// Measure gradient, update, and eval costs for one artifact variant.
pub fn measure_costs(exes: &ModelExecutables, opt: &OptimizerConfig,
                     reps: usize) -> Calibration {
    let meta = &exes.meta;
    let mut rng = Rng::new(0xCA11B);
    let params = exes.init_params(&mut rng);
    let x: Vec<f32> = (0..meta.x_len())
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let y: Vec<i32> = (0..meta.batch)
        .map(|_| rng.usize_below(meta.classes) as i32)
        .collect();

    exes.grad_step(&params, &x, &y).expect("calibration grad"); // warm
    let t0 = Instant::now();
    for _ in 0..reps {
        exes.grad_step(&params, &x, &y).expect("calibration grad");
    }
    let t_grad = t0.elapsed().as_secs_f64() / reps as f64;

    let t0 = Instant::now();
    for _ in 0..reps {
        exes.eval_step(&params, &x, &y).expect("calibration eval");
    }
    let t_eval_batch = t0.elapsed().as_secs_f64() / reps as f64;

    let mut optimizer = opt.build(meta.param_count);
    let mut w = ParamSet::zeros(&meta.params);
    let g = vec![1e-3f32; meta.param_count];
    let t0 = Instant::now();
    let ureps = 1000;
    for _ in 0..ureps {
        optimizer.update(w.flat_mut(), &g);
    }
    let t_update = t0.elapsed().as_secs_f64() / ureps as f64;

    Calibration { t_grad, batch: meta.batch, t_update, t_eval_batch }
}

impl Calibration {
    /// Project the gradient time to another batch size, splitting the
    /// measured cost into a fixed dispatch part and a per-sample part.
    /// The fixed fraction is itself measured when a batch-10 artifact is
    /// available (see `apply_with_small_batch`); this fallback assumes
    /// 15% fixed, which matches the measured LSTM dispatch share.
    pub fn apply(&self, cost: &mut CostModel) {
        let fixed = 0.15 * self.t_grad;
        cost.t_grad_fixed = fixed;
        cost.t_grad_per_sample = (self.t_grad - fixed)
            / self.batch as f64;
        cost.t_update = self.t_update;
        cost.t_val = 0.0;
    }

    /// Two-point calibration from a second, smaller-batch measurement:
    /// solves t(b) = fixed + b * per_sample exactly.
    pub fn apply_with_small_batch(&self, small: &Calibration,
                                  cost: &mut CostModel) {
        let db = (self.batch - small.batch) as f64;
        let per_sample = ((self.t_grad - small.t_grad) / db).max(1e-9);
        let fixed = (small.t_grad
            - small.batch as f64 * per_sample).max(0.0);
        cost.t_grad_fixed = fixed;
        cost.t_grad_per_sample = per_sample;
        cost.t_update = self.t_update;
    }
}
