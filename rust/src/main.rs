//! `mpi-learn` CLI — the launcher binary.
//!
//! Subcommands:
//!   gen-data   generate the synthetic HEP benchmark dataset shards
//!   train      run a distributed training session (`train --help`)
//!   simulate   run the cluster-scale protocol simulator
//!   info       list AOT artifacts and their interfaces
//!   rank       run ONE rank of a TCP-mesh job (SPMD deployment)
//!   launch     spawn one `rank` process per rank and wait
//!   serve      HTTP inference front-end over a checkpoint dir
//!              (`serve --help`)
//!
//! Examples:
//!   mpi-learn gen-data --dir data/hep --files 16 --samples 2000
//!   mpi-learn train --model lstm --batch 100 --workers 4 --epochs 10 \
//!       --data data/hep --validate-every 50
//!   mpi-learn train --mode easgd --tau 10 --alpha 0.5 --workers 4 \
//!       --data data/hep
//!   mpi-learn train --mode allreduce --model mlp --workers 8 \
//!       --epochs 3                      # masterless ring all-reduce
//!   mpi-learn train --mode allreduce --workers 8 --compression fp16
//!   mpi-learn train --mode allreduce --hierarchy --groups 2 \
//!       --workers 8                     # hierarchical all-reduce:
//!       # two 4-rank intra-group rings + an inter-group leader tree
//!   mpi-learn train --workers 4 --compression topk:0.1  # sparsified
//!       # gradient uplink with error feedback
//!   mpi-learn train --model mlp --workers 4 --validate-every 20 \
//!       --early-stopping 3 --checkpoint runs/ckpt   # callbacks
//!   mpi-learn simulate --workers 1,2,4,8,16,30,45,60 --preset cluster
//!   mpi-learn simulate --algo allreduce --preset cluster
//!   mpi-learn simulate --algo hier-allreduce --groups 4 \
//!       --workers 16,32,64              # grouped ring + leader tree
//!   mpi-learn info
//!   mpi-learn serve --model lstm --checkpoint-dir runs/ckpt \
//!       --port 8080 --max-batch 32      # then:
//!       # curl -d '{"instances": [[...]]}' localhost:8080/v1/predict

use std::path::PathBuf;

use mpi_learn::coordinator::{self, Algo, CallbackSpec, Data,
                             HierarchySpec, Mode, ModelBuilder,
                             TrainConfig, Transport};
use mpi_learn::data::{generate_dataset, list_train_files,
                      GeneratorConfig};
use mpi_learn::mpi::Codec;
use mpi_learn::optim::OptimizerConfig;
use mpi_learn::runtime::Session;
use mpi_learn::simulator::{self, CostModel, SimConfig};
use mpi_learn::util::cli::Args;

fn main() {
    mpi_learn::util::logging::init();
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("gen-data") => cmd_gen_data(&args),
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("info") => cmd_info(&args),
        Some("rank") => cmd_rank(&args),
        Some("launch") => cmd_launch(&args),
        Some("serve") => cmd_serve(&args),
        _ => {
            eprintln!("usage: mpi-learn \
                       <gen-data|train|simulate|info|rank|launch|serve> \
                       [flags]  (try: mpi-learn train --help)");
            2
        }
    };
    std::process::exit(code);
}

fn open_session(artifacts: Option<String>)
    -> Result<Session, mpi_learn::runtime::SessionError> {
    match artifacts {
        Some(dir) => Session::open(&PathBuf::from(dir)),
        None => Session::open_default(),
    }
}

fn print_result(r: &mpi_learn::coordinator::TrainResult) {
    let h = &r.history;
    println!("trained in {:.2}s: {} master updates, {:.0} samples/s",
             r.wallclock_s, h.master_updates,
             h.throughput_samples_per_s());
    if let Some(v) = h.validations.last() {
        println!("final validation: loss={:.4} acc={:.4}", v.val_loss,
                 v.val_acc);
    }
    print!("{}", h.validations_csv());
}

/// SPMD: run one rank of a TCP-mesh job (`mpirun`-style, one process per
/// rank). All ranks must share the same --config and --base-port.
fn cmd_rank(args: &Args) -> i32 {
    let rank = match args.usize("rank", usize::MAX) {
        Ok(r) if r != usize::MAX => r,
        _ => return fail("rank requires --rank <i>"),
    };
    let base_port = args.u64("base-port", 47500).unwrap_or(47500) as u16;
    let config = args.str_opt("config");
    let artifacts = args.str_opt("artifacts");
    if let Err(e) = args.finish() {
        return fail(e);
    }
    let Some(config) = config else {
        return fail("rank requires --config <job.json>");
    };
    let job = match mpi_learn::coordinator::JobConfig::from_file(
        &PathBuf::from(config)) {
        Ok(j) => j,
        Err(e) => return fail(e),
    };
    let session = match open_session(artifacts) {
        Ok(s) => s,
        Err(e) => return fail(e),
    };
    match mpi_learn::coordinator::run_rank(&session, &job.train,
                                           &job.data, rank, base_port) {
        Ok(Some(result)) => {
            print_result(&result);
            0
        }
        Ok(None) => 0,
        Err(e) => fail(e),
    }
}

/// Launcher: spawn one OS process per rank (this binary, `rank`
/// subcommand) and wait — the `mpirun` of this framework.
fn cmd_launch(args: &Args) -> i32 {
    let base_port = args.u64("base-port", 47500).unwrap_or(47500) as u16;
    let config = args.str_opt("config");
    let artifacts = args.str_opt("artifacts");
    if let Err(e) = args.finish() {
        return fail(e);
    }
    let Some(config) = config else {
        return fail("launch requires --config <job.json>");
    };
    let job = match mpi_learn::coordinator::JobConfig::from_file(
        &PathBuf::from(&config)) {
        Ok(j) => j,
        Err(e) => return fail(e),
    };
    // WorldPlan is the single source of truth for world size (a
    // hand-rolled copy here went stale when grouped allreduce landed:
    // its world is masterless even though a hierarchy spec is present)
    let size = match mpi_learn::coordinator::WorldPlan::new(&job.train) {
        Ok(plan) => plan.world_size(),
        Err(e) => return fail(e),
    };
    let exe = match std::env::current_exe() {
        Ok(e) => e,
        Err(e) => return fail(e),
    };
    println!("launching {size} rank processes (base port {base_port})");
    let mut children = Vec::new();
    for rank in 0..size {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("rank")
            .arg("--rank").arg(rank.to_string())
            .arg("--base-port").arg(base_port.to_string())
            .arg("--config").arg(&config);
        if let Some(a) = &artifacts {
            cmd.arg("--artifacts").arg(a);
        }
        match cmd.spawn() {
            Ok(child) => children.push((rank, child)),
            Err(e) => return fail(format!("spawn rank {rank}: {e}")),
        }
    }
    let mut code = 0;
    for (rank, mut child) in children {
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                eprintln!("rank {rank} exited with {status}");
                code = 1;
            }
            Err(e) => {
                eprintln!("rank {rank} wait failed: {e}");
                code = 1;
            }
        }
    }
    code
}

fn fail(e: impl std::fmt::Display) -> i32 {
    eprintln!("error: {e}");
    1
}

/// One row of the `train` flag table — the single source the `--help`
/// usage text is generated from.
struct Flag {
    name: &'static str,
    /// Value placeholder; empty for boolean flags.
    value: &'static str,
    default: &'static str,
    help: &'static str,
}

const TRAIN_FLAGS: &[Flag] = &[
    Flag { name: "config", value: "<job.json>", default: "",
           help: "load the whole job from a JSON config file" },
    Flag { name: "model", value: "<family>", default: "lstm",
           help: "model family: mlp | lstm | transformer" },
    Flag { name: "batch", value: "<n>", default: "100",
           help: "batch size (selects the compiled variant)" },
    Flag { name: "workers", value: "<n>", default: "4",
           help: "worker count (== ranks in allreduce mode)" },
    Flag { name: "epochs", value: "<n>", default: "10",
           help: "training epochs" },
    Flag { name: "mode", value: "<m>", default: "downpour",
           help: "algorithm: downpour | easgd | allreduce" },
    Flag { name: "sync", value: "", default: "",
           help: "downpour: synchronous barrier rounds" },
    Flag { name: "tau", value: "<n>", default: "10",
           help: "easgd: exchange period in batches" },
    Flag { name: "alpha", value: "<f>", default: "0.5",
           help: "easgd: elastic force coefficient" },
    Flag { name: "compression", value: "<c>", default: "fp32",
           help: "wire codec: fp32 | fp16 | topk:<k> (gradient \
                  compression with error feedback)" },
    Flag { name: "buckets", value: "", default: "",
           help: "allreduce: per-layer bucketed all-reduce overlapped \
                  with backprop (identical results, less comm wait)" },
    Flag { name: "elastic", value: "", default: "",
           help: "allreduce: survive rank churn — replan the ring over \
                  survivors and resume (see docs/RUNBOOK.md)" },
    Flag { name: "elastic-timeout-ms", value: "<ms>", default: "30000",
           help: "elastic: dead-peer suspicion + membership agreement \
                  window" },
    Flag { name: "auto", value: "", default: "",
           help: "allreduce: self-tune the topology — probe the links, \
                  calibrate the cost model, and let the planner pick \
                  flat-vs-hier, groups, codec, and bucketing" },
    Flag { name: "retune-factor", value: "<f>", default: "2.0",
           help: "auto: re-plan when a window's measured round time \
                  exceeds factor x the planner's prediction" },
    Flag { name: "retune-window", value: "<n>", default: "50",
           help: "auto: rounds per re-tuner measurement window" },
    Flag { name: "threads", value: "<n>", default: "0",
           help: "compute threads per rank for the native kernel pool \
                  (GEMMs, activations, optimizer steps, fp16 codec); \
                  0 = auto-detect; results are bitwise-identical at \
                  any value" },
    Flag { name: "optimizer", value: "<o>", default: "momentum",
           help: "sgd | momentum | adam | rmsprop | adadelta" },
    Flag { name: "lr", value: "<f>", default: "0.05",
           help: "base learning rate" },
    Flag { name: "momentum", value: "<f>", default: "0.9",
           help: "momentum coefficient" },
    Flag { name: "lr-decay", value: "<f>", default: "0",
           help: "LR step decay factor (0 = off)" },
    Flag { name: "lr-decay-every", value: "<n>", default: "0",
           help: "apply LR decay every N master updates" },
    Flag { name: "validate-every", value: "<n>", default: "0",
           help: "validate every N master updates (0 = end only)" },
    Flag { name: "max-val-batches", value: "<n>", default: "0",
           help: "cap validation batches per sweep (0 = all)" },
    Flag { name: "early-stopping", value: "<patience>", default: "0",
           help: "stop after N non-improving validations (0 = off)" },
    Flag { name: "min-delta", value: "<f>", default: "0",
           help: "early stopping: minimum val-loss improvement" },
    Flag { name: "checkpoint", value: "<dir>", default: "",
           help: "write best-val checkpoint to <dir>/best.mplw" },
    Flag { name: "checkpoint-every", value: "<n>", default: "0",
           help: "also write checkpoint-{update}.mplw every N updates" },
    Flag { name: "jsonl", value: "<path>", default: "",
           help: "stream round/validation metrics as JSON lines" },
    Flag { name: "data", value: "<dir>", default: "",
           help: "train_*.mpil shard dir (default: synthetic data)" },
    Flag { name: "hierarchy", value: "", default: "",
           help: "two-level topology (needs --groups >= 2): grouped \
                  masters (downpour) or intra-group ring + inter-group \
                  leader tree (allreduce)" },
    Flag { name: "groups", value: "<n>", default: "0",
           help: "group count of the two-level topology (>= 2, <= \
                  --workers; 0 = flat)" },
    Flag { name: "sync-every", value: "<n>", default: "10",
           help: "hierarchy: group master upward sync period" },
    Flag { name: "tcp", value: "", default: "",
           help: "carry the protocol over a localhost TCP mesh" },
    Flag { name: "seed", value: "<n>", default: "2017",
           help: "RNG seed (init + batch order)" },
    Flag { name: "direct", value: "", default: "",
           help: "no-framework single-process baseline (paper \u{a7}V)" },
    Flag { name: "artifacts", value: "<dir>", default: "",
           help: "AOT artifact dir (default: native backend)" },
    Flag { name: "help", value: "", default: "",
           help: "print this usage text" },
];

fn train_usage() -> String {
    let mut out = String::from(
        "usage: mpi-learn train [--config job.json | flags]\n\nflags:\n");
    for f in TRAIN_FLAGS {
        let mut left = format!("--{}", f.name);
        if !f.value.is_empty() {
            left.push(' ');
            left.push_str(f.value);
        }
        out.push_str(&format!("  {left:<28} {}", f.help));
        if !f.default.is_empty() {
            out.push_str(&format!(" [default: {}]", f.default));
        }
        out.push('\n');
    }
    out
}

const SERVE_FLAGS: &[Flag] = &[
    Flag { name: "config", value: "<serve.json>", default: "",
           help: "load the serve config from a JSON file (bare object \
                  or a \"serve\" block in a job.json)" },
    Flag { name: "model", value: "<family>", default: "lstm",
           help: "model family: mlp | lstm (must match checkpoints)" },
    Flag { name: "checkpoint-dir", value: "<dir>", default: "runs/ckpt",
           help: "dir a training run writes *.mplw checkpoints into; \
                  polled for hot reload" },
    Flag { name: "port", value: "<n>", default: "8080",
           help: "HTTP listen port (0 = ephemeral)" },
    Flag { name: "max-batch", value: "<n>", default: "32",
           help: "rows per forward pass: micro-batch flush threshold \
                  and per-request row cap" },
    Flag { name: "batch-deadline-ms", value: "<ms>", default: "5",
           help: "flush a partial micro-batch after this long" },
    Flag { name: "replicas", value: "<n>", default: "0",
           help: "inference replica ranks to fan batches over \
                  (0 = in-process, no replica pool)" },
    Flag { name: "tcp", value: "", default: "",
           help: "carry replica traffic over a localhost TCP mesh" },
    Flag { name: "base-port", value: "<n>", default: "47800",
           help: "first port of the replica TCP mesh (with --tcp)" },
    Flag { name: "poll-ms", value: "<ms>", default: "500",
           help: "checkpoint dir poll interval" },
    Flag { name: "replica-timeout-ms", value: "<ms>", default: "2000",
           help: "per-batch replica deadline before mark-dead + retry" },
    Flag { name: "threads", value: "<n>", default: "0",
           help: "compute threads for the kernel pool behind each \
                  forward pass (0 = auto-detect; predictions are \
                  bitwise-identical at any value)" },
    Flag { name: "help", value: "", default: "",
           help: "print this usage text" },
];

fn serve_usage() -> String {
    let mut out = String::from(
        "usage: mpi-learn serve [--config serve.json | flags]\n\n\
         flags:\n");
    for f in SERVE_FLAGS {
        let mut left = format!("--{}", f.name);
        if !f.value.is_empty() {
            left.push(' ');
            left.push_str(f.value);
        }
        out.push_str(&format!("  {left:<28} {}", f.help));
        if !f.default.is_empty() {
            out.push_str(&format!(" [default: {}]", f.default));
        }
        out.push('\n');
    }
    out
}

/// HTTP inference front-end: micro-batching, optional replica ranks,
/// hot checkpoint reload. Runs until killed.
fn cmd_serve(args: &Args) -> i32 {
    if args.bool("help") {
        print!("{}", serve_usage());
        return 0;
    }
    let cfg = if let Some(config) = args.str_opt("config") {
        if let Err(e) = args.finish() {
            return fail(e);
        }
        match mpi_learn::serving::ServeConfig::from_file(
            &PathBuf::from(config)) {
            Ok(c) => c,
            Err(e) => return fail(e),
        }
    } else {
        let defaults = mpi_learn::serving::ServeConfig::default();
        let cfg = mpi_learn::serving::ServeConfig {
            model: args.str("model", &defaults.model),
            checkpoint_dir: PathBuf::from(
                args.str("checkpoint-dir", "runs/ckpt")),
            port: args.u64("port", defaults.port as u64)
                .unwrap_or(defaults.port as u64) as u16,
            max_batch: args.usize("max-batch", defaults.max_batch)
                .unwrap_or(defaults.max_batch),
            batch_deadline_ms: args
                .u64("batch-deadline-ms", defaults.batch_deadline_ms)
                .unwrap_or(defaults.batch_deadline_ms),
            replicas: args.usize("replicas", defaults.replicas)
                .unwrap_or(defaults.replicas),
            tcp: args.bool("tcp"),
            base_port: args.u64("base-port", defaults.base_port as u64)
                .unwrap_or(defaults.base_port as u64) as u16,
            poll_ms: args.u64("poll-ms", defaults.poll_ms)
                .unwrap_or(defaults.poll_ms),
            replica_timeout_ms: args
                .u64("replica-timeout-ms", defaults.replica_timeout_ms)
                .unwrap_or(defaults.replica_timeout_ms),
            threads: args.usize("threads", defaults.threads)
                .unwrap_or(defaults.threads),
        };
        if let Err(e) = args.finish() {
            return fail(e);
        }
        cfg
    };
    match mpi_learn::serving::run_serve(&cfg) {
        Ok(()) => 0,
        Err(e) => fail(e),
    }
}

/// Callback flags shared by the flag-driven `train` path.
fn parse_callbacks(args: &Args) -> Result<Vec<CallbackSpec>, String> {
    let mut specs = Vec::new();
    let patience = args.usize("early-stopping", 0)
        .map_err(|e| e.to_string())?;
    let min_delta = args.f64("min-delta", 0.0)
        .map_err(|e| e.to_string())? as f32;
    if patience > 0 {
        specs.push(CallbackSpec::EarlyStopping {
            patience: patience as u32,
            min_delta,
        });
    }
    let every = args.usize("checkpoint-every", 0)
        .map_err(|e| e.to_string())? as u64;
    match args.str_opt("checkpoint") {
        Some(dir) => specs.push(CallbackSpec::ModelCheckpoint {
            dir: PathBuf::from(dir),
            every,
            best_only: every == 0,
        }),
        None if every > 0 => {
            return Err("--checkpoint-every needs --checkpoint <dir>"
                .into())
        }
        None => {}
    }
    if let Some(path) = args.str_opt("jsonl") {
        specs.push(CallbackSpec::JsonlLogger {
            path: PathBuf::from(path),
        });
    }
    Ok(specs)
}

fn cmd_gen_data(args: &Args) -> i32 {
    let dir = PathBuf::from(args.str("dir", "data/hep"));
    let files = args.usize("files", 16).unwrap_or(16);
    let samples = args.usize("samples", 2000).unwrap_or(2000);
    let val_samples = args.usize("val-samples", 2000).unwrap_or(2000);
    let cfg = GeneratorConfig {
        seed: args.u64("seed", 2017).unwrap_or(2017),
        separation: args.f64("separation", 0.6).unwrap_or(0.6) as f32,
        ..Default::default()
    };
    if let Err(e) = args.finish() {
        return fail(e);
    }
    match generate_dataset(&cfg, &dir, files, samples, val_samples) {
        Ok((train, val)) => {
            println!("wrote {} train shards + {} to {}", train.len(),
                     val.display(), dir.display());
            0
        }
        Err(e) => fail(e),
    }
}

fn parse_algo(args: &Args) -> Result<Algo, String> {
    let mut algo = Algo {
        batch_size: args.usize("batch", 100).map_err(|e| e.to_string())?,
        epochs: args.usize("epochs", 10).map_err(|e| e.to_string())?
            as u32,
        validate_every: args.usize("validate-every", 0)
            .map_err(|e| e.to_string())? as u64,
        max_val_batches: args.usize("max-val-batches", 0)
            .map_err(|e| e.to_string())?,
        ..Algo::default()
    };
    let lr = args.f64("lr", 0.05).map_err(|e| e.to_string())? as f32;
    let momentum = args.f64("momentum", 0.9).map_err(|e| e.to_string())?
        as f32;
    algo.lr_decay = args.f64("lr-decay", 0.0)
        .map_err(|e| e.to_string())? as f32;
    algo.lr_decay_every = args.usize("lr-decay-every", 0)
        .map_err(|e| e.to_string())? as u64;
    algo.optimizer = match args.str("optimizer", "momentum").as_str() {
        "sgd" => OptimizerConfig::Sgd { lr },
        "momentum" => OptimizerConfig::Momentum { lr, momentum,
                                                  nesterov: false },
        "adam" => OptimizerConfig::Adam { lr, beta1: 0.9, beta2: 0.999,
                                          eps: 1e-8 },
        "rmsprop" => OptimizerConfig::RmsProp { lr, rho: 0.9, eps: 1e-7 },
        "adadelta" => OptimizerConfig::AdaDelta { rho: 0.95, eps: 1e-6 },
        other => return Err(format!("unknown optimizer '{other}'")),
    };
    algo.compression =
        Codec::parse(&args.str("compression", "fp32"))?;
    algo.buckets = args.bool("buckets");
    algo.elastic = args.bool("elastic");
    algo.elastic_timeout_ms = args.usize("elastic-timeout-ms", 30_000)
        .map_err(|e| e.to_string())? as u64;
    algo.auto = args.bool("auto");
    algo.retune_factor = args.f64("retune-factor", 2.0)
        .map_err(|e| e.to_string())?;
    if algo.retune_factor <= 1.0 {
        return Err(format!(
            "--retune-factor must be > 1.0 (got {}): the re-tuner \
             triggers on measured > factor x predicted",
            algo.retune_factor));
    }
    algo.retune_window = args.usize("retune-window", 50)
        .map_err(|e| e.to_string())? as u64;
    if algo.retune_window == 0 {
        return Err("--retune-window must be >= 1 round".into());
    }
    algo.threads = args.usize("threads", 0).map_err(|e| e.to_string())?;
    algo.mode = match args.str("mode", "downpour").as_str() {
        "downpour" => Mode::Downpour { sync: args.bool("sync") },
        "easgd" => Mode::Easgd {
            tau: args.usize("tau", 10).map_err(|e| e.to_string())? as u32,
            alpha: args.f64("alpha", 0.5).map_err(|e| e.to_string())?
                as f32,
            worker_optimizer: OptimizerConfig::Sgd { lr },
        },
        "allreduce" => Mode::AllReduce,
        other => return Err(format!("unknown mode '{other}'")),
    };
    Ok(algo)
}

fn cmd_train(args: &Args) -> i32 {
    if args.bool("help") {
        print!("{}", train_usage());
        return 0;
    }
    // config-file driven path: `train --config job.json`
    if let Some(config) = args.str_opt("config") {
        let direct = args.bool("direct");
        let artifacts = args.str_opt("artifacts");
        if let Err(e) = args.finish() {
            return fail(e);
        }
        let job = match mpi_learn::coordinator::JobConfig::from_file(
            &PathBuf::from(config)) {
            Ok(j) => j,
            Err(e) => return fail(e),
        };
        let session = match open_session(artifacts) {
            Ok(s) => s,
            Err(e) => return fail(e),
        };
        let result = if direct {
            coordinator::train_direct(&session, &job.train, &job.data)
        } else {
            coordinator::train(&session, &job.train, &job.data)
        };
        return match result {
            Ok(r) => {
                print_result(&r);
                0
            }
            Err(e) => fail(e),
        };
    }

    let model = args.str("model", "lstm");
    let workers = args.usize("workers", 4).unwrap_or(4);
    let algo = match parse_algo(args) {
        Ok(a) => a,
        Err(e) => return fail(e),
    };
    let callbacks = match parse_callbacks(args) {
        Ok(c) => c,
        Err(e) => return fail(e),
    };
    let data_dir = args.str_opt("data");
    let direct = args.bool("direct");
    let tcp = args.bool("tcp");
    let hierarchy_flag = args.bool("hierarchy");
    let groups = args.usize("groups", 0).unwrap_or(0);
    let sync_every = args.usize("sync-every", 10).unwrap_or(10) as u64;
    let seed = args.u64("seed", 2017).unwrap_or(2017);
    let artifacts = args.str_opt("artifacts");
    if let Err(e) = args.finish() {
        return fail(e);
    }

    // Parse-time --groups validation (ISSUE 4 satellite): errors name
    // the flags to fix instead of surfacing from deep inside train().
    if hierarchy_flag && groups < 2 {
        return fail(format!(
            "--hierarchy requires --groups >= 2 (got {groups})"));
    }
    // --auto hands the topology decision to the planner; an explicit
    // topology flag next to it would silently lose one or the other.
    if algo.auto && (hierarchy_flag || groups > 0) {
        return fail(
            "--auto and --hierarchy/--groups are mutually exclusive: \
             drop the topology flags to let the planner pick the \
             grouping, or drop --auto to pin it");
    }
    if algo.auto && algo.mode != Mode::AllReduce {
        return fail(
            "--auto requires --mode allreduce: the planner tunes ring \
             topologies, not parameter-server worlds");
    }
    if algo.auto && direct {
        return fail(
            "--auto has nothing to tune under --direct (single \
             process, no collectives)");
    }
    if groups > 0 {
        if groups < 2 {
            return fail(format!(
                "--groups must be >= 2 (got {groups}); omit it for a \
                 flat world"));
        }
        if groups > workers {
            return fail(format!(
                "--groups ({groups}) must be <= --workers ({workers}): \
                 every group needs at least one worker"));
        }
        if workers % groups != 0 {
            return fail(format!(
                "--workers ({workers}) must divide evenly into \
                 --groups ({groups}) equal groups"));
        }
    }

    let data = match data_dir {
        Some(dir) => {
            let dir = PathBuf::from(dir);
            let train = match list_train_files(&dir) {
                Ok(t) if !t.is_empty() => t,
                Ok(_) => return fail(format!(
                    "no train_*.mpil shards in {} (run gen-data)",
                    dir.display())),
                Err(e) => return fail(e),
            };
            Data::Files { train, val: dir.join("val.mpil") }
        }
        None => Data::Synthetic {
            gen: GeneratorConfig::default(),
            samples_per_worker: 2000,
            val_samples: 1000,
        },
    };

    let mut cfg = TrainConfig {
        builder: ModelBuilder::new(&model, algo.batch_size),
        algo,
        n_workers: workers,
        seed,
        transport: if tcp { Transport::Tcp { base_port: 47000 } }
                   else { Transport::Inproc },
        hierarchy: None,
        callbacks,
    };
    if groups > 0 {
        cfg.hierarchy = Some(HierarchySpec {
            n_groups: groups,
            workers_per_group: workers / groups.max(1),
            sync_every,
        });
    }

    let session = match artifacts {
        Some(dir) => Session::open(&PathBuf::from(dir)),
        None => Session::open_default(),
    };
    let session = match session {
        Ok(s) => s,
        Err(e) => return fail(e),
    };

    let result = if direct {
        coordinator::train_direct(&session, &cfg, &data)
    } else {
        coordinator::train(&session, &cfg, &data)
    };
    match result {
        Ok(r) => {
            let h = &r.history;
            println!("trained in {:.2}s: {} master updates, \
                      {:.0} samples/s",
                     r.wallclock_s, h.master_updates,
                     h.throughput_samples_per_s());
            if let Some(v) = h.validations.last() {
                println!("final validation: loss={:.4} acc={:.4}",
                         v.val_loss, v.val_acc);
            }
            print!("{}", h.validations_csv());
            0
        }
        Err(e) => fail(e),
    }
}

fn cmd_simulate(args: &Args) -> i32 {
    let worker_counts = args
        .usize_list("workers", &[1, 2, 4, 8, 16, 30, 45, 60])
        .unwrap_or_default();
    let preset = args.str("preset", "cluster");
    let batch = args.usize("batch", 100).unwrap_or(100);
    let total = args.u64("total-samples", 950_000).unwrap_or(950_000);
    let epochs = args.usize("epochs", 10).unwrap_or(10) as u32;
    let validate_every = args.usize("validate-every", 0).unwrap_or(0)
        as u64;
    let n_params = args.usize("params", 3023).unwrap_or(3023);
    let algo = args.str("algo", "downpour");
    let compression = args.str("compression", "fp32");
    let groups = args.usize("groups", 4).unwrap_or(4);
    if let Err(e) = args.finish() {
        return fail(e);
    }
    if groups < 2 {
        return fail(format!("--groups must be >= 2 (got {groups})"));
    }
    let cost = match preset.as_str() {
        "shared" => CostModel::shared_memory(n_params),
        "cluster" => CostModel::cluster(n_params),
        other => return fail(format!("unknown preset '{other}'")),
    };
    let cost = match Codec::parse(&compression) {
        Ok(codec) => cost.with_compression(codec),
        Err(e) => return fail(e),
    };
    let base = SimConfig {
        n_workers: 1,
        total_samples: total,
        batch,
        epochs,
        validate_every,
        sync: false,
    };
    let curve = match algo.as_str() {
        "downpour" => simulator::speedup_curve(&cost, &base,
                                               &worker_counts, 2017),
        "allreduce" => simulator::speedup_curve_allreduce(
            &cost, &base, &worker_counts, 2017),
        "hier-allreduce" => simulator::speedup_curve_hier_allreduce(
            &cost, &base, &worker_counts, groups, 2017),
        other => return fail(format!(
            "unknown simulate algo '{other}' \
             (downpour|allreduce|hier-allreduce)")),
    };
    println!("workers,speedup");
    for (w, s) in curve {
        println!("{w},{s:.2}");
    }
    0
}

fn cmd_info(args: &Args) -> i32 {
    let artifacts = args.str_opt("artifacts");
    if let Err(e) = args.finish() {
        return fail(e);
    }
    let session = match artifacts {
        Some(dir) => Session::open(&PathBuf::from(dir)),
        None => Session::open_default(),
    };
    match session {
        Ok(s) => {
            println!("platform: {}", s.client.platform());
            for m in &s.manifest.models {
                println!(
                    "{:20} model={:12} batch={:5} params={:8} \
                     x=[{},{},{}]",
                    m.key, m.model, m.batch, m.param_count, m.batch,
                    m.seq_len, m.features
                );
            }
            0
        }
        Err(e) => fail(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite (ISSUE 2): the `train --help` usage text is generated
    /// from the one flag table — every row appears, no drift possible.
    #[test]
    fn usage_lists_every_train_flag() {
        let usage = train_usage();
        for f in TRAIN_FLAGS {
            assert!(usage.contains(&format!("--{}", f.name)),
                    "usage is missing --{}", f.name);
            if !f.default.is_empty() {
                assert!(usage.contains(&format!("[default: {}]",
                                                f.default)),
                        "usage is missing the default of --{}", f.name);
            }
        }
        assert!(usage.starts_with("usage: mpi-learn train"));
    }

    #[test]
    fn usage_lists_every_serve_flag() {
        let usage = serve_usage();
        for f in SERVE_FLAGS {
            assert!(usage.contains(&format!("--{}", f.name)),
                    "serve usage is missing --{}", f.name);
            if !f.default.is_empty() {
                assert!(usage.contains(&format!("[default: {}]",
                                                f.default)),
                        "serve usage is missing the default of --{}",
                        f.name);
            }
        }
        assert!(usage.starts_with("usage: mpi-learn serve"));
    }

    #[test]
    fn auto_flags_parse_and_validate() {
        let args = Args::parse(
            ["train", "--mode", "allreduce", "--auto"]
                .iter().map(|s| s.to_string()).collect());
        let a = parse_algo(&args).unwrap();
        assert!(a.auto);
        assert_eq!(a.retune_factor, 2.0);
        assert_eq!(a.retune_window, 50);
        // a trigger factor at or below 1.0 would fire on every window
        let args = Args::parse(
            ["train", "--mode", "allreduce", "--auto",
             "--retune-factor", "0.5"]
                .iter().map(|s| s.to_string()).collect());
        let err = parse_algo(&args).unwrap_err();
        assert!(err.contains("retune-factor"), "{err}");
        let args = Args::parse(
            ["train", "--mode", "allreduce", "--retune-window", "0"]
                .iter().map(|s| s.to_string()).collect());
        let err = parse_algo(&args).unwrap_err();
        assert!(err.contains("retune-window"), "{err}");
    }

    #[test]
    fn callback_flags_build_specs() {
        let args = Args::parse(
            ["train", "--early-stopping", "3", "--checkpoint", "/tmp/c",
             "--checkpoint-every", "50", "--jsonl", "/tmp/m.jsonl"]
                .iter()
                .map(|s| s.to_string())
                .collect());
        let specs = parse_callbacks(&args).unwrap();
        assert_eq!(specs.len(), 3);
        assert!(matches!(specs[0], CallbackSpec::EarlyStopping {
            patience: 3, .. }));
        assert!(matches!(specs[1], CallbackSpec::ModelCheckpoint {
            every: 50, best_only: false, .. }));
        assert!(matches!(specs[2], CallbackSpec::JsonlLogger { .. }));
        // no callback flags -> no specs
        let args = Args::parse(vec!["train".to_string()]);
        assert!(parse_callbacks(&args).unwrap().is_empty());
        // an orphan --checkpoint-every must error, not vanish
        let args = Args::parse(
            ["train", "--checkpoint-every", "10"]
                .iter()
                .map(|s| s.to_string())
                .collect());
        assert!(parse_callbacks(&args).is_err());
    }
}
