//! End-to-end elastic-worlds tests (ISSUE 8): a rank killed mid-run
//! must pause the world, replan the ring over the survivors within the
//! timeout budget, and resume with bitwise-identical weights on every
//! survivor; a joiner must be re-admitted through the same agreement
//! path and receive replicated weights. Runs on the native CPU backend.

use std::time::Duration;

use mpi_learn::coordinator::callbacks::Observer;
use mpi_learn::coordinator::validation::run_validation;
use mpi_learn::coordinator::worker::{ReshardFn, RingWorker,
                                     WorkerError};
use mpi_learn::coordinator::{Algo, HierarchySpec, Mode, WorldPlan};
use mpi_learn::data::{generate_shard, DataSet, GeneratorConfig, Shard};
use mpi_learn::runtime::Session;
use mpi_learn::util::rng::Rng;

/// Short suspicion window so recovery fits a unit-test budget; the
/// production default is 30 s (`--elastic-timeout-ms`).
const TIMEOUT: Duration = Duration::from_millis(500);

/// One fixed sample pool, carved into `m` contiguous shards — the same
/// re-sharding rule the driver's `Data::worker_dataset` applies, so a
/// replanned world trains on the identical data divided differently.
fn pool(samples: usize) -> Shard {
    let gen = GeneratorConfig { seed: 21, ..Default::default() };
    generate_shard(&gen, samples, &mut Rng::new(3))
}

fn shard_for(pos: usize, m: usize, samples: usize) -> DataSet {
    let p = pool(samples);
    let per = p.n_samples() / m;
    let (a, b) = (pos * per, (pos + 1) * per);
    let sl = p.sample_len();
    DataSet::from_shard(Shard {
        seq_len: p.seq_len,
        features: p.features,
        classes: p.classes,
        labels: p.labels[a..b].to_vec(),
        x: p.x[a * sl..b * sl].to_vec(),
    })
}

fn elastic_algo(epochs: u32) -> Algo {
    Algo {
        mode: Mode::AllReduce,
        batch_size: 10,
        epochs,
        elastic: true,
        ..Algo::default()
    }
}

fn val_set() -> DataSet {
    let gen = GeneratorConfig { seed: 77, ..Default::default() };
    DataSet::from_shard(generate_shard(&gen, 200, &mut Rng::new(9)))
}

/// ISSUE 8 acceptance: 8 ranks in 2 groups, one killed mid-run. The
/// survivors pause, agree on the 7-member world (the grouped schedule
/// falls back to a flat ring — 7 does not divide into 2 groups),
/// re-shard, resume, and finish with bitwise-identical weights; the
/// accuracy lands close to an uninterrupted 7-rank run on the same
/// re-sharded data.
#[test]
fn kill_one_rank_mid_run_survivors_replan_and_stay_bitwise_identical() {
    const SAMPLES: usize = 560; // 8 ranks x 7 rounds, 7 ranks x 8
    let session = Session::native().unwrap();
    let exes = session.executables("mlp_b10").unwrap();
    let algo = elastic_algo(2);
    let plan = WorldPlan::from_parts(
        &Mode::AllReduce,
        Some(HierarchySpec { n_groups: 2, workers_per_group: 4,
                             sync_every: 1 }),
        8, 11)
        .unwrap();
    let init = exes.init_params(&mut Rng::new(7));
    let resharder: &ReshardFn =
        &|pos, m| Ok(shard_for(pos, m, SAMPLES));

    let world = mpi_learn::mpi::inproc_world(8);
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let algo = &algo;
                let plan = plan.clone();
                let exes = exes.clone();
                let init = if rank == 0 { Some(init.clone()) }
                           else { None };
                s.spawn(move || {
                    let ds = shard_for(rank, 8, SAMPLES);
                    let mut w = RingWorker::new(&comm, algo, &exes, &ds,
                                                100 + rank as u64, None)
                        .with_groups(plan.ring_layout())
                        .with_elastic(plan, TIMEOUT)
                        .with_resharder(resharder);
                    if rank == 5 {
                        // die right after epoch 0 (7 updates)
                        w = w.with_fault_after(7);
                    }
                    w.run(init, &mut Observer::disabled())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // the killed rank crashed on cue, without stats or wind-down
    match &results[5] {
        Err(WorkerError::FaultInjected) => {}
        other => panic!("rank 5 should have crashed on cue, got \
                         {:?}", other.as_ref().map(|_| "Ok")),
    }
    // every survivor finished, with bitwise-identical weights
    let survivors: Vec<usize> =
        (0..8).filter(|&r| r != 5).collect();
    let reference = results[0].as_ref().unwrap();
    for &r in &survivors[1..] {
        let out = results[r].as_ref().unwrap_or_else(|e| {
            panic!("survivor {r} failed: {e}")
        });
        assert_eq!(out.weights, reference.weights,
                   "survivor {r} diverged after the replan");
    }
    // deterministic work accounting: 7 updates in the 8-rank epoch 0,
    // then the interrupted epoch 1 replayed as 8 rounds of the 7-rank
    // world
    assert_eq!(reference.history.master_updates, 7 + 8);

    // accuracy close to an uninterrupted 7-rank run on the same
    // re-sharded data (trajectories differ pre-churn, so this is a
    // closeness bound, not bitwise)
    let uninterrupted: Vec<_> = {
        let world = mpi_learn::mpi::inproc_world(7);
        std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .enumerate()
                .map(|(rank, comm)| {
                    let algo = &algo;
                    let exes = exes.clone();
                    let init = if rank == 0 { Some(init.clone()) }
                               else { None };
                    s.spawn(move || {
                        let ds = shard_for(rank, 7, SAMPLES);
                        RingWorker::new(&comm, algo, &exes, &ds,
                                        100 + rank as u64, None)
                            .run(init, &mut Observer::disabled())
                            .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    };
    let val = val_set();
    let (_, acc_churn) = run_validation(
        &exes, &reference.weights, &val, 0).unwrap();
    let (_, acc_ref) = run_validation(
        &exes, &uninterrupted[0].weights, &val, 0).unwrap();
    assert!(acc_churn > 0.5, "churned run collapsed: acc {acc_churn}");
    assert!((acc_churn - acc_ref).abs() <= 0.15,
            "churned acc {acc_churn} strayed from uninterrupted \
             {acc_ref}");
}

/// Scale-up: a rank excluded from the launch plan knocks on the door
/// (ElasticJoin), the coordinator folds it in at a round boundary via
/// the same agreement path, and the joiner resumes from replicated
/// weights — all four ranks finish bitwise-identical.
#[test]
fn joiner_is_admitted_and_receives_replicated_weights() {
    const SAMPLES: usize = 240; // 3 ranks x 8 rounds, 4 ranks x 6
    let session = Session::native().unwrap();
    let exes = session.executables("mlp_b10").unwrap();
    let algo = elastic_algo(2);
    let full = WorldPlan::from_parts(&Mode::AllReduce, None, 4, 11)
        .unwrap();
    // launch with rank 3 excluded: epoch 1, members [0, 1, 2]
    let initial = full.replan(&[0, 1, 2]).unwrap();
    let init = exes.init_params(&mut Rng::new(7));
    let resharder: &ReshardFn =
        &|pos, m| Ok(shard_for(pos, m, SAMPLES));

    let world = mpi_learn::mpi::inproc_world(4);
    let results: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let algo = &algo;
                let initial = initial.clone();
                let exes = exes.clone();
                let init = if rank == 0 { Some(init.clone()) }
                           else { None };
                s.spawn(move || {
                    // the joiner's launch shard is never trained: the
                    // resharder re-shards before its first round
                    let ds = shard_for(rank.min(2), 3, SAMPLES);
                    RingWorker::new(&comm, algo, &exes, &ds,
                                    100 + rank as u64, None)
                        .with_elastic(initial, TIMEOUT)
                        .with_resharder(resharder)
                        .run(init, &mut Observer::disabled())
                        .unwrap_or_else(|e| {
                            panic!("rank {rank} failed: {e}")
                        })
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // all four ranks — including the joiner — hold identical weights
    let reference = &results[0];
    for (rank, out) in results.iter().enumerate().skip(1) {
        assert_eq!(out.weights, reference.weights,
                   "rank {rank} diverged (joiner admission broke \
                    replication)");
    }
    // the grown world re-ran the interrupted epoch at 6 rounds per
    // epoch; however early the join lands, both epochs complete in the
    // 4-member world
    assert!(reference.history.master_updates >= 12,
            "got {} updates", reference.history.master_updates);
}
