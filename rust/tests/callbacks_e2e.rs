//! End-to-end tests of the callback layer (ISSUE 2): EarlyStopping
//! fires at the right round and every rank exits cleanly in every
//! training mode; ModelCheckpoint's best-val checkpoint reloads
//! bitwise-identically; `WorldPlan` invariants hold for random
//! configurations. Runs on the native CPU backend — no artifacts.

use mpi_learn::coordinator::{train, Algo, CallbackSpec, Data,
                             Experiment, HierarchySpec, Mode, RankRole,
                             TrainConfig, Transport, WorldPlan};
use mpi_learn::data::GeneratorConfig;
use mpi_learn::optim::OptimizerConfig;
use mpi_learn::runtime::Session;
use mpi_learn::tensor::ParamSet;
use mpi_learn::util::prop::{check, gen, PropConfig};

fn synthetic(samples_per_worker: usize) -> Data {
    Data::Synthetic {
        gen: GeneratorConfig { seed: 5, ..Default::default() },
        samples_per_worker,
        val_samples: 200,
    }
}

fn cfg(mode: Mode, workers: usize) -> TrainConfig {
    TrainConfig {
        algo: Algo {
            mode,
            batch_size: 10,
            epochs: 5,
            validate_every: 5,
            max_val_batches: 2,
            ..Algo::default()
        },
        ..TrainConfig::new("mlp", 10, workers)
    }
}

/// An infinite `min_delta` makes every validation a non-improvement,
/// so with patience P the stop fires deterministically at validation
/// number P — i.e. at master update `validate_every * P`.
fn never_improves(patience: u32) -> CallbackSpec {
    CallbackSpec::EarlyStopping { patience,
                                  min_delta: f32::INFINITY }
}

/// EarlyStopping must stop at exactly `validate_every * patience`
/// updates and wind every rank down cleanly (train returns Ok) in
/// every training mode. Without the stop each of these runs would do
/// hundreds of updates.
#[test]
fn early_stopping_fires_at_the_right_round_in_every_mode() {
    let session = Session::native().unwrap();

    let modes: Vec<(&str, Mode, usize)> = vec![
        ("downpour-async", Mode::Downpour { sync: false }, 2),
        ("downpour-sync", Mode::Downpour { sync: true }, 2),
        ("easgd", Mode::Easgd {
            tau: 2,
            alpha: 0.5,
            worker_optimizer: OptimizerConfig::Sgd { lr: 0.05 },
        }, 2),
        ("allreduce", Mode::AllReduce, 3),
    ];
    for (name, mode, workers) in modes {
        let mut c = cfg(mode, workers);
        c.callbacks.push(never_improves(2));
        let r = train(&session, &c, &synthetic(400)).unwrap_or_else(
            |e| panic!("{name}: {e}"));
        assert_eq!(r.history.master_updates, 10,
                   "{name}: stop must land at validate_every * \
                    patience = 10 updates");
    }

    // hierarchical: the super-master validates per sync and orders the
    // whole tree down through the group masters
    let mut c = cfg(Mode::Downpour { sync: false }, 2);
    c.hierarchy = Some(HierarchySpec {
        n_groups: 2,
        workers_per_group: 1,
        sync_every: 2,
    });
    c.algo.validate_every = 1;
    c.callbacks.push(never_improves(2));
    let r = train(&session, &c, &synthetic(400)).unwrap();
    assert_eq!(r.history.master_updates, 2,
               "hierarchical: stop at the 2nd super-master update");

    // grouped (hierarchical) allreduce: the piggybacked stop flag must
    // survive the ring → tree → ring schedule so every rank abandons
    // the flagged round in lockstep
    let mut c = cfg(Mode::AllReduce, 4);
    c.hierarchy = Some(HierarchySpec {
        n_groups: 2,
        workers_per_group: 2,
        sync_every: 1,
    });
    c.callbacks.push(never_improves(2));
    let r = train(&session, &c, &synthetic(400)).unwrap();
    assert_eq!(r.history.master_updates, 10,
               "hier-allreduce: stop at validate_every * patience");

    // direct baseline: the same observer drives the same stop
    let mut c = cfg(Mode::Downpour { sync: false }, 1);
    c.callbacks.push(never_improves(2));
    let r = mpi_learn::coordinator::train_direct(&session, &c,
                                                 &synthetic(400))
        .unwrap();
    assert_eq!(r.history.master_updates, 10);
}

/// A genuinely-improving run must NOT be stopped: training converges,
/// so val loss keeps falling and the patience counter never fills.
#[test]
fn early_stopping_does_not_fire_while_improving() {
    let session = Session::native().unwrap();
    let mut c = cfg(Mode::AllReduce, 2);
    c.algo.epochs = 2;
    c.callbacks.push(CallbackSpec::EarlyStopping {
        patience: 10,
        min_delta: 0.0,
    });
    let r = train(&session, &c, &synthetic(200)).unwrap();
    // 200 samples / batch 10 = 20 rounds per epoch, 2 epochs
    assert_eq!(r.history.master_updates, 40, "no premature stop");
}

/// Acceptance (ISSUE 2): an Experiment-driven allreduce run with
/// EarlyStopping + ModelCheckpoint produces a best-val checkpoint that
/// reloads bitwise-identically.
#[test]
fn experiment_best_checkpoint_reloads_bitwise_in_allreduce() {
    let dir = std::env::temp_dir().join("mpi_learn_e2e_best_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let session = Session::native().unwrap();
    let result = Experiment::new("mlp")
        .batch(20)
        .workers(4)
        .allreduce()
        .epochs(2)
        .synthetic(200, 200)
        .max_val_batches(4)
        .early_stopping(5) // attached, must not fire
        .checkpoint(&dir)
        .run(&session)
        .unwrap();
    // validate_every defaults to 0 -> the final validation is the only
    // (and best) one, so best.mplw holds the final weights exactly
    let best = ParamSet::load(&dir.join("best.mplw")).unwrap();
    assert_eq!(best, result.weights,
               "best checkpoint must reload bitwise-identically");
    assert_eq!(result.history.master_updates, 2 * 10,
               "early stopping must not have fired");
}

/// The JSONL logger streams from inside a distributed run.
#[test]
fn jsonl_logger_streams_from_training() {
    let path = std::env::temp_dir()
        .join("mpi_learn_e2e_jsonl/metrics.jsonl");
    let _ = std::fs::remove_file(&path);
    let session = Session::native().unwrap();
    let mut c = cfg(Mode::Downpour { sync: false }, 2);
    c.algo.epochs = 1;
    c.callbacks.push(CallbackSpec::JsonlLogger { path: path.clone() });
    train(&session, &c, &synthetic(100)).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.lines().count() >= 3, "begin + rounds + end");
    assert!(text.contains("\"event\":\"validation\""));
    for line in text.lines() {
        mpi_learn::util::json::Json::parse(line).unwrap();
    }
}

/// WorldPlan invariants over random configurations: rank 0 is always
/// the observer role, roles partition the world, shard indices are a
/// permutation of 0..n_shards, per-shard seeds are distinct, grouped
/// allreduce plans put every rank in exactly one group with the leaders
/// forming a connected binary tree, and the plan is independent of the
/// transport.
#[test]
fn prop_worldplan_invariants() {
    check("worldplan", PropConfig { cases: 300, seed: 0x70B0 }, |rng| {
        let mode = match rng.usize_below(4) {
            0 => Mode::Downpour { sync: false },
            1 => Mode::Downpour { sync: true },
            2 => Mode::Easgd {
                tau: 4,
                alpha: 0.5,
                worker_optimizer: OptimizerConfig::Sgd { lr: 0.05 },
            },
            _ => Mode::AllReduce,
        };
        let hierarchy = if matches!(mode, Mode::Downpour { .. }
                                          | Mode::AllReduce)
            && rng.uniform() < 0.5 {
            Some(HierarchySpec {
                n_groups: gen::usize_in(rng, 2, 4),
                workers_per_group: gen::usize_in(rng, 1, 4),
                sync_every: gen::usize_in(rng, 1, 10) as u64,
            })
        } else {
            None
        };
        let workers = gen::usize_in(rng, 1, 12);
        let seed = rng.next_u64();
        let plan = WorldPlan::from_parts(&mode, hierarchy, workers,
                                         seed)
            .map_err(|e| format!("unexpected rejection: {e}"))?;

        let size = plan.world_size();
        let ring = matches!(mode, Mode::AllReduce);
        let mut masters = 0usize;
        let mut shards = Vec::new();
        let mut shard_seeds = Vec::new();
        for r in 0..size {
            match plan.role_of(r) {
                RankRole::Master => {
                    masters += 1;
                    if r != plan.observer() {
                        return Err(format!("master at rank {r}"));
                    }
                }
                RankRole::GroupMaster { .. } => {
                    if hierarchy.is_none() {
                        return Err("group master without \
                                    hierarchy".into());
                    }
                }
                RankRole::Worker { master, shard } => {
                    shards.push(shard);
                    shard_seeds.push(plan.seed_of(r));
                    match plan.role_of(master) {
                        RankRole::Master
                        | RankRole::GroupMaster { .. } => {}
                        other => {
                            return Err(format!(
                                "worker {r} reports to non-master \
                                 {other:?}"))
                        }
                    }
                }
                RankRole::RingRank { shard, group } => {
                    if !ring {
                        return Err("ring rank outside allreduce".into());
                    }
                    match hierarchy {
                        Some(h) if group >= h.n_groups => {
                            return Err(format!(
                                "rank {r} in out-of-range group \
                                 {group}"))
                        }
                        None if group != 0 => {
                            return Err(format!(
                                "flat ring rank {r} in group {group}"))
                        }
                        _ => {}
                    }
                    shards.push(shard);
                    shard_seeds.push(plan.seed_of(r));
                }
            }
        }
        if ring && masters != 0 {
            return Err("allreduce world has a master".into());
        }
        // grouped-allreduce layout invariants: every rank in exactly
        // one group, the role's group matches the layout, and the
        // leaders form a connected binary tree (every non-root leader's
        // parent position is a valid leader position)
        match plan.ring_layout() {
            Some(layout) => {
                if !(ring && hierarchy.is_some()) {
                    return Err("layout on a non-grouped plan".into());
                }
                let mut seen = vec![0usize; size];
                for (g, members) in layout.groups().iter().enumerate() {
                    if members.is_empty() {
                        return Err(format!("group {g} is empty"));
                    }
                    for &r in members {
                        if r >= size {
                            return Err(format!(
                                "group {g} member {r} outside world"));
                        }
                        seen[r] += 1;
                        match plan.role_of(r) {
                            RankRole::RingRank { group, .. }
                                if group == g => {}
                            other => {
                                return Err(format!(
                                    "rank {r} in layout group {g} but \
                                     role {other:?}"))
                            }
                        }
                    }
                }
                if seen.iter().any(|&c| c != 1) {
                    return Err(format!(
                        "ranks not in exactly one group: {seen:?}"));
                }
                // leader-tree structure: one leader per group, each
                // the head (minimum rank) of its own group, strictly
                // ascending — which is what makes the positional
                // binary tree (parent (p-1)/2) well-defined and rooted
                // at the observer
                let leaders = layout.leaders();
                if leaders.len() != layout.groups().len() {
                    return Err("one leader per group".into());
                }
                for (g, (&leader, members)) in leaders
                    .iter()
                    .zip(layout.groups().iter())
                    .enumerate()
                {
                    if members.first() != Some(&leader)
                        || members.iter().min() != Some(&leader)
                    {
                        return Err(format!(
                            "leader {leader} is not the head of \
                             group {g}: {members:?}"));
                    }
                    if g > 0 && leaders[g - 1] >= leader {
                        return Err(format!(
                            "leaders not strictly ascending: \
                             {leaders:?}"));
                    }
                }
                if leaders[0] != plan.observer() {
                    return Err("tree root must be the observer \
                                rank 0".into());
                }
            }
            None => {
                if ring && hierarchy.is_some() {
                    return Err("grouped allreduce plan without a \
                                layout".into());
                }
            }
        }
        if !ring && masters != 1 {
            return Err(format!("{masters} masters"));
        }
        // shard indices: a permutation of 0..n_shards (contiguous,
        // each trained exactly once)
        shards.sort_unstable();
        let want: Vec<usize> = (0..plan.n_shards()).collect();
        if shards != want {
            return Err(format!("shards not contiguous: {shards:?}"));
        }
        // per-shard seeds distinct
        shard_seeds.sort_unstable();
        shard_seeds.dedup();
        if shard_seeds.len() != plan.n_shards() {
            return Err("duplicate shard seeds".into());
        }
        // transport independence: the identical plan for inproc & TCP
        let mut c = TrainConfig::new("mlp", 10, workers);
        c.algo.mode = mode.clone();
        c.hierarchy = hierarchy;
        c.seed = seed;
        c.transport = Transport::Inproc;
        let p1 = WorldPlan::new(&c).map_err(|e| e)?;
        c.transport = Transport::Tcp { base_port: 47999 };
        let p2 = WorldPlan::new(&c).map_err(|e| e)?;
        if p1 != p2 || p1 != plan {
            return Err("plan depends on transport".into());
        }
        // elastic replans (ISSUE 8): a random survivor subset (rank 0
        // always survives — its death ends the job) must yield a
        // coherent, strictly-newer world
        if ring {
            let mut survivors: Vec<usize> = (1..size)
                .filter(|_| rng.uniform() < 0.7)
                .collect();
            survivors.push(0);
            let rp = plan.replan(&survivors)
                .map_err(|e| format!("replan rejected: {e}"))?;
            if rp.epoch() != plan.epoch() + 1 {
                return Err(format!("replan epoch {} after {}",
                                   rp.epoch(), plan.epoch()));
            }
            let members = rp.members()
                .ok_or("replanned plan must list members")?;
            let mut want = survivors.clone();
            want.sort_unstable();
            want.dedup();
            if members != want.as_slice() {
                return Err(format!(
                    "members {members:?} != survivors {want:?}"));
            }
            // shards cover 0..m exactly once, in member order
            let m = members.len();
            let mut rshards: Vec<usize> = members
                .iter()
                .map(|&r| match rp.role_of(r) {
                    RankRole::RingRank { shard, .. } => Ok(shard),
                    other => Err(format!(
                        "member {r} got role {other:?}")),
                })
                .collect::<Result<_, _>>()?;
            rshards.sort_unstable();
            if rshards != (0..m).collect::<Vec<_>>() {
                return Err(format!(
                    "replanned shards not 0..{m}: {rshards:?}"));
            }
            match rp.ring_layout() {
                Some(layout) => {
                    // grouped replans partition the members exactly once
                    let flat: Vec<usize> = layout
                        .groups()
                        .iter()
                        .flat_map(|g| g.iter().copied())
                        .collect();
                    let mut sorted = flat.clone();
                    sorted.sort_unstable();
                    if sorted != members {
                        return Err(format!(
                            "layout {flat:?} is not a partition of \
                             {members:?}"));
                    }
                }
                None if m == 1 => {} // degrades to local training
                None => {}           // flat ring (or non-divisible)
            }
            if m == 1 && rp.ring_layout().is_some() {
                return Err("1-member world must not have a grouped \
                            layout".into());
            }
            // chained churn: epochs strictly increase; re-admitting
            // every departed rank restores the launch grouping
            let rp2 = rp.replan(&[0])
                .map_err(|e| format!("second replan: {e}"))?;
            if rp2.epoch() != rp.epoch() + 1 {
                return Err("epochs must increase per replan".into());
            }
            let departed: Vec<usize> =
                (0..size).filter(|r| !members.contains(r)).collect();
            let grown = rp.replan_grown(&departed)
                .map_err(|e| format!("replan_grown: {e}"))?;
            let full: Vec<usize> = (0..size).collect();
            if grown.members() != Some(full.as_slice()) {
                return Err("grow-back must restore full \
                            membership".into());
            }
            if grown.ring_layout().map(|l| l.groups().to_vec())
                != plan.ring_layout().map(|l| l.groups().to_vec())
            {
                return Err("grow-back must restore the launch \
                            grouping".into());
            }
            // a rank that was never in the world cannot survive, and
            // rank 0 cannot be dropped
            if plan.replan(&[0, size]).is_ok() {
                return Err("foreign rank accepted".into());
            }
            if size > 1 && plan.replan(&[1]).is_ok() {
                return Err("world without rank 0 accepted".into());
            }
        }
        Ok(())
    });
}

/// Early stopping over the TCP transport: the Exit propagation must
/// behave identically on the socket mesh.
#[test]
fn early_stopping_over_tcp() {
    let session = Session::native().unwrap();
    let mut c = cfg(Mode::Downpour { sync: false }, 2);
    c.transport = Transport::Tcp { base_port: 46240 };
    c.callbacks.push(never_improves(2));
    let r = train(&session, &c, &synthetic(400)).unwrap();
    assert_eq!(r.history.master_updates, 10);
}
