//! End-to-end tests of the masterless all-reduce training mode, running
//! on the native CPU backend — no artifacts needed, so unlike the PJRT
//! integration suite these always run.

use mpi_learn::coordinator::callbacks::Observer;
use mpi_learn::coordinator::worker::RingWorker;
use mpi_learn::coordinator::{train, Algo, Data, HierarchySpec, Mode,
                             ModelBuilder, TrainConfig, Transport};
use mpi_learn::data::{generate_shard, DataSet, GeneratorConfig};
use mpi_learn::runtime::Session;
use mpi_learn::util::rng::Rng;

fn allreduce_cfg(workers: usize, batch: usize, epochs: u32)
    -> TrainConfig {
    TrainConfig {
        builder: ModelBuilder::new("mlp", batch),
        algo: Algo {
            mode: Mode::AllReduce,
            batch_size: batch,
            epochs,
            validate_every: 0,
            max_val_batches: 4,
            ..Algo::default()
        },
        n_workers: workers,
        seed: 11,
        transport: Transport::Inproc,
        hierarchy: None,
        callbacks: Vec::new(),
    }
}

fn synthetic(samples_per_worker: usize) -> Data {
    Data::Synthetic {
        gen: GeneratorConfig { seed: 5, ..Default::default() },
        samples_per_worker,
        val_samples: 250,
    }
}

#[test]
fn allreduce_trains_quickstart_model_end_to_end() {
    // Acceptance: Mode::AllReduce trains the quickstart model (mlp) on
    // the inproc transport with >= 4 ranks.
    let session = Session::native().unwrap();
    let cfg = allreduce_cfg(4, 25, 2);
    let result = train(&session, &cfg, &synthetic(250)).unwrap();
    // 250 samples / batch 25 = 10 rounds per epoch, 2 epochs
    assert_eq!(result.history.master_updates, 20);
    // every rank reported its stats to rank 0
    assert_eq!(result.history.workers.len(), 4);
    for w in &result.history.workers {
        assert_eq!(w.batches, 20);
        assert_eq!(w.epochs, 2);
    }
    let acc = result.history.final_val_acc().expect("final validation");
    assert!(acc > 0.6, "final val acc {acc}");
    assert!(result.history.staleness_mean == 0.0,
            "synchronous mode is never stale");
}

#[test]
fn allreduce_ranks_end_bitwise_identical() {
    // The replicated-optimizer invariant: every rank finishes with the
    // exact same bytes in its ParamSet.
    let session = Session::native().unwrap();
    let exes = session.executables("mlp_b10").unwrap();
    let n = 4;
    let algo = Algo {
        mode: Mode::AllReduce,
        batch_size: 10,
        epochs: 2,
        ..Algo::default()
    };
    let gen = GeneratorConfig { seed: 21, ..Default::default() };
    let mut rng = Rng::new(3);
    let datasets: Vec<DataSet> = (0..n)
        .map(|_| DataSet::from_shard(generate_shard(&gen, 80, &mut rng)))
        .collect();
    let init = exes.init_params(&mut Rng::new(7));

    let world = mpi_learn::mpi::inproc_world(n);
    let outcomes: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let ds = &datasets[rank];
                let algo = &algo;
                let exes = exes.clone();
                let init = if rank == 0 { Some(init.clone()) }
                           else { None };
                s.spawn(move || {
                    RingWorker::new(&comm, algo, &exes, ds,
                                    100 + rank as u64, None)
                        .run(init, &mut Observer::disabled())
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let reference = &outcomes[0].weights;
    assert_ne!(reference, &init, "training must have moved the weights");
    for (rank, outcome) in outcomes.iter().enumerate().skip(1) {
        assert_eq!(&outcome.weights, reference,
                   "rank {rank} diverged from rank 0");
    }
    // 80 samples / batch 10 = 8 rounds per epoch, 2 epochs
    for outcome in &outcomes {
        assert_eq!(outcome.report.batches, 16);
    }
}

#[test]
fn allreduce_uneven_data_agrees_on_common_rounds() {
    // Ranks with different local dataset sizes must agree on the
    // minimum round count instead of deadlocking the lockstep ring.
    let session = Session::native().unwrap();
    let exes = session.executables("mlp_b10").unwrap();
    let algo = Algo {
        mode: Mode::AllReduce,
        batch_size: 10,
        epochs: 1,
        ..Algo::default()
    };
    let gen = GeneratorConfig { seed: 31, ..Default::default() };
    let mut rng = Rng::new(4);
    // 100 samples -> 10 local batches vs 37 samples -> 3 local batches
    let sizes = [100usize, 37];
    let datasets: Vec<DataSet> = sizes
        .iter()
        .map(|&s| DataSet::from_shard(generate_shard(&gen, s, &mut rng)))
        .collect();
    let init = exes.init_params(&mut Rng::new(8));

    let world = mpi_learn::mpi::inproc_world(2);
    let outcomes: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let ds = &datasets[rank];
                let algo = &algo;
                let exes = exes.clone();
                let init = if rank == 0 { Some(init.clone()) }
                           else { None };
                s.spawn(move || {
                    RingWorker::new(&comm, algo, &exes, ds,
                                    200 + rank as u64, None)
                        .run(init, &mut Observer::disabled())
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for outcome in &outcomes {
        assert_eq!(outcome.report.batches, 3,
                   "both ranks run min(10, 3) common rounds");
    }
    assert_eq!(outcomes[0].weights, outcomes[1].weights);
}

#[test]
fn allreduce_training_is_deterministic() {
    let session = Session::native().unwrap();
    let cfg = allreduce_cfg(3, 20, 1);
    let data = synthetic(200);
    let r1 = train(&session, &cfg, &data).unwrap();
    let r2 = train(&session, &cfg, &data).unwrap();
    assert_eq!(r1.weights, r2.weights,
               "lockstep all-reduce is schedule-independent");
    assert_eq!(r1.history.master_updates, r2.history.master_updates);
}

#[test]
fn allreduce_works_over_tcp() {
    let session = Session::native().unwrap();
    let mut cfg = allreduce_cfg(3, 20, 1);
    cfg.transport = Transport::Tcp { base_port: 46550 };
    let result = train(&session, &cfg, &synthetic(100)).unwrap();
    assert_eq!(result.history.master_updates, 5);
    assert_eq!(result.history.workers.len(), 3);
}

#[test]
fn allreduce_with_hierarchy_trains_grouped() {
    // ISSUE 4 tentpole: hierarchy + allreduce now plans a grouped
    // masterless world (2 rings of 2 + a leader tree) and trains
    // end-to-end. The dedicated equivalence suite lives in
    // tests/hier_allreduce.rs.
    let session = Session::native().unwrap();
    let mut cfg = allreduce_cfg(4, 20, 1);
    cfg.hierarchy = Some(HierarchySpec {
        n_groups: 2,
        workers_per_group: 2,
        sync_every: 5,
    });
    let result = train(&session, &cfg, &synthetic(100)).unwrap();
    assert_eq!(result.history.master_updates, 5);
    assert_eq!(result.history.workers.len(), 4);
}

#[test]
fn allreduce_single_group_hierarchy_rejected() {
    let session = Session::native().unwrap();
    let mut cfg = allreduce_cfg(4, 20, 1);
    cfg.hierarchy = Some(HierarchySpec {
        n_groups: 1,
        workers_per_group: 4,
        sync_every: 5,
    });
    let err = train(&session, &cfg, &synthetic(100));
    assert!(err.is_err(), "a one-group hierarchy is rejected");
}

#[test]
fn downpour_still_trains_on_native_backend() {
    // The pre-existing parameter-server path also runs end-to-end on
    // the native backend (previously it needed AOT artifacts).
    let session = Session::native().unwrap();
    let cfg = TrainConfig {
        builder: ModelBuilder::new("mlp", 20),
        algo: Algo {
            batch_size: 20,
            epochs: 2,
            max_val_batches: 4,
            ..Algo::default()
        },
        n_workers: 2,
        seed: 13,
        transport: Transport::Inproc,
        hierarchy: None,
        callbacks: Vec::new(),
    };
    let result = train(&session, &cfg, &synthetic(200)).unwrap();
    assert_eq!(result.history.master_updates, 2 * 2 * 10);
    let acc = result.history.final_val_acc().expect("final validation");
    assert!(acc > 0.6, "downpour-on-native final val acc {acc}");
}
