//! End-to-end serving tests (ISSUE 7 acceptance): boot the full stack
//! on an ephemeral port, hammer `POST /v1/predict` from concurrent
//! clients while a new checkpoint lands mid-flight, and prove
//!
//! * zero requests fail across the hot swap (every response is 200),
//! * every response is bitwise-identical to a fresh `predict_rows`
//!   call on a freshly-loaded `ParamSet` for the `weight_version` the
//!   response claims, and
//! * `/healthz` reports the new version without a restart.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mpi_learn::runtime::{ModelExecutables, Session};
use mpi_learn::serving::http::client_request;
use mpi_learn::serving::{self, ServeConfig};
use mpi_learn::tensor::ParamSet;
use mpi_learn::util::json::Json;
use mpi_learn::util::rng::Rng;

const MODEL: &str = "mlp";
const MAX_BATCH: usize = 4;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mpi_learn_serve_e2e_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn exe() -> Arc<ModelExecutables> {
    Session::native()
        .unwrap()
        .executables(&format!("{MODEL}_b{MAX_BATCH}"))
        .unwrap()
}

fn cfg(dir: &std::path::Path, replicas: usize) -> ServeConfig {
    ServeConfig {
        model: MODEL.into(),
        checkpoint_dir: dir.to_path_buf(),
        port: 0,
        max_batch: MAX_BATCH,
        batch_deadline_ms: 1,
        replicas,
        tcp: false,
        base_port: 47900,
        poll_ms: 10,
        replica_timeout_ms: 5_000,
        threads: 1,
    }
}

/// Deterministic request row: every (thread, iteration, element) slot
/// gets a fixed value, so the validation pass can rebuild the exact
/// input from the recorded floats alone.
fn row(t: usize, i: usize, row_len: usize) -> Vec<f32> {
    (0..row_len)
        .map(|k| (((t * 997 + i * 31 + k) % 89) as f32) * 0.02 - 0.9)
        .collect()
}

fn body_for(x: &[f32], rows: usize, row_len: usize) -> String {
    let rows: Vec<String> = (0..rows)
        .map(|r| {
            let cells: Vec<String> = x[r * row_len..(r + 1) * row_len]
                .iter()
                // f32 -> f64 is exact; {:?} round-trips the f64.
                .map(|v| format!("{:?}", *v as f64))
                .collect();
            format!("[{}]", cells.join(","))
        })
        .collect();
    format!("{{\"instances\": [{}]}}", rows.join(","))
}

struct Reply {
    rows: usize,
    x: Vec<f32>,
    version: u64,
    logits: Vec<f32>,
}

fn parse_reply(body: &str, rows: usize, x: Vec<f32>, classes: usize)
    -> Reply {
    let j = Json::parse(body).unwrap();
    let version = j.get("weight_version").unwrap().as_i64().unwrap()
        as u64;
    let preds = j.get("predictions").unwrap().as_arr().unwrap();
    assert_eq!(preds.len(), rows, "one prediction row per input row");
    let mut logits = Vec::with_capacity(rows * classes);
    for p in preds {
        let p = p.as_arr().unwrap();
        assert_eq!(p.len(), classes);
        logits.extend(p.iter().map(|v| v.as_f64().unwrap() as f32));
    }
    Reply { rows, x, version, logits }
}

/// Drive concurrent clients through a hot swap; returns every reply.
fn hammer_through_swap(tag: &str, replicas: usize) -> Vec<Reply> {
    let exe = exe();
    let row_len = exe.meta.seq_len * exe.meta.features;
    let classes = exe.meta.classes;
    let dir = tmpdir(tag);

    let p1 = exe.init_params(&mut Rng::new(1));
    let p2 = exe.init_params(&mut Rng::new(2));
    assert_ne!(p1.flat(), p2.flat(), "the swap must be observable");
    p1.save(&dir.join("checkpoint-1.mplw")).unwrap();

    let mut handle = serving::start(&cfg(&dir, replicas)).unwrap();
    let addr = handle.addr();

    // Booted from the checkpoint, not Glorot init.
    let (status, body) = client_request(addr, "GET", "/healthz", "")
        .unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("weight_version").unwrap().as_i64(), Some(0));
    assert!(j.get("weight_source").unwrap().as_str().unwrap()
        .contains("checkpoint-1"), "{body}");

    let stop = Arc::new(AtomicBool::new(false));
    let clients: Vec<_> = (0..4)
        .map(|t| {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut replies = Vec::new();
                let mut i = 0;
                while !stop.load(Ordering::Relaxed) && i < 400 {
                    let rows = 1 + (t + i) % 2;
                    let mut x = Vec::new();
                    for r in 0..rows {
                        x.extend(row(t, i * 2 + r, row_len));
                    }
                    let (status, body) = client_request(
                        addr, "POST", "/v1/predict",
                        &body_for(&x, rows, row_len))
                        .unwrap();
                    assert_eq!(status, 200,
                               "request failed during hot swap: {body}");
                    replies.push(parse_reply(&body, rows, x, classes));
                    i += 1;
                }
                replies
            })
        })
        .collect();

    // Let traffic flow on v0, then drop the new checkpoint mid-load.
    std::thread::sleep(Duration::from_millis(150));
    p2.save(&dir.join("checkpoint-2.mplw")).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    while handle.state().version() < 1 {
        assert!(Instant::now() < deadline, "reload never happened");
        std::thread::sleep(Duration::from_millis(5));
    }
    // Keep hammering on the new weights for a bit, then stop.
    std::thread::sleep(Duration::from_millis(150));
    stop.store(true, Ordering::Relaxed);
    let replies: Vec<Reply> = clients
        .into_iter()
        .flat_map(|c| c.join().unwrap())
        .collect();

    // /healthz shows the bump — same process, no restart.
    let (status, body) = client_request(addr, "GET", "/healthz", "")
        .unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("weight_version").unwrap().as_i64(), Some(1),
               "{body}");
    assert!(j.get("weight_source").unwrap().as_str().unwrap()
        .contains("checkpoint-2"), "{body}");
    handle.stop();

    // Bitwise validation against FRESH loads of the two checkpoints,
    // keyed by the version each response claims it was computed with.
    let v0 = ParamSet::load(&dir.join("checkpoint-1.mplw")).unwrap();
    let v1 = ParamSet::load(&dir.join("checkpoint-2.mplw")).unwrap();
    let (mut on_v0, mut on_v1) = (0usize, 0usize);
    for r in &replies {
        let params = match r.version {
            0 => {
                on_v0 += 1;
                &v0
            }
            1 => {
                on_v1 += 1;
                &v1
            }
            v => panic!("impossible weight_version {v}"),
        };
        let want = exe.predict_rows(params, &r.x, r.rows).unwrap();
        let want_bits: Vec<u32> =
            want.iter().map(|f| f.to_bits()).collect();
        let got_bits: Vec<u32> =
            r.logits.iter().map(|f| f.to_bits()).collect();
        assert_eq!(got_bits, want_bits,
                   "response not bitwise-identical to a fresh \
                    predict on weights v{}", r.version);
    }
    assert!(on_v0 > 0, "no traffic was served on the boot weights");
    assert!(on_v1 > 0, "no traffic was served on the new weights");
    replies
}

#[test]
fn hot_swap_under_load_drops_zero_requests() {
    let replies = hammer_through_swap("local", 0);
    assert!(replies.len() >= 8);
}

#[test]
fn hot_swap_with_replica_pool_drops_zero_requests() {
    let replies = hammer_through_swap("replicas", 2);
    assert!(replies.len() >= 8);
}
