//! Property tests for the collective-communication subsystem: the ring
//! all-reduce must equal a serial reduction — bitwise, because each
//! element is reduced exactly once in ring order — for random world
//! sizes (2–8) and lengths that exercise non-divisible and
//! smaller-than-world chunk splits, on both transports.

use mpi_learn::mpi::collective::{Collective, ReduceOp};
use mpi_learn::mpi::{self, Comm};
use mpi_learn::util::prop::{check, gen, PropConfig};

/// Serial reference matching the ring's deterministic reduction order:
/// chunk `c` starts from rank `c`'s contribution and accumulates ranks
/// c+1, …, c+n-1 (mod n).
fn ring_order_reference(inputs: &[Vec<f32>], op: ReduceOp) -> Vec<f32> {
    let n = inputs.len();
    let len = inputs[0].len();
    let mut out = vec![0.0f32; len];
    for c in 0..n {
        let (lo, hi) = Collective::chunk_bounds(len, n, c);
        for j in lo..hi {
            let mut acc = inputs[c][j];
            for k in 1..n {
                let v = inputs[(c + k) % n][j];
                match op {
                    ReduceOp::Sum => acc += v,
                    ReduceOp::Min => acc = acc.min(v),
                    ReduceOp::Max => acc = acc.max(v),
                }
            }
            out[j] = acc;
        }
    }
    out
}

fn run_world(world: Vec<Comm>, inputs: &[Vec<f32>], op: ReduceOp)
    -> Vec<Vec<f32>> {
    std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .zip(inputs.iter())
            .map(|(comm, input)| {
                let mut buf = input.clone();
                s.spawn(move || {
                    let mut col = Collective::new(&comm);
                    col.allreduce(&mut buf, op).unwrap();
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn prop_ring_allreduce_equals_serial_reduction() {
    check("ring-allreduce", PropConfig { cases: 60, seed: 0x51C6 },
          |rng| {
        let n = gen::usize_in(rng, 2, 8);
        // lengths around (and below) the world size force empty and
        // uneven chunks; larger ones exercise the bulk path
        let len = match rng.usize_below(4) {
            0 => gen::usize_in(rng, 0, n),           // <= world size
            1 => gen::usize_in(rng, n + 1, 3 * n),   // non-divisible
            2 => gen::usize_in(rng, 1, 50),
            _ => gen::usize_in(rng, 100, 2000),
        };
        let op = match rng.usize_below(3) {
            0 => ReduceOp::Sum,
            1 => ReduceOp::Min,
            _ => ReduceOp::Max,
        };
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| gen::f32_vec(rng, len, 3.0))
            .collect();
        let reference = ring_order_reference(&inputs, op);
        let results = run_world(mpi::inproc_world(n), &inputs, op);
        for (rank, got) in results.iter().enumerate() {
            if got != &reference {
                return Err(format!(
                    "rank {rank} diverged (n={n}, len={len}, op={op:?})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn ring_allreduce_over_tcp_transport() {
    // The same lockstep schedule must hold over the socket mesh.
    let n = 3;
    let len = 257; // non-divisible by 3
    let mut rng = mpi_learn::util::rng::Rng::new(9);
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let reference = ring_order_reference(&inputs, ReduceOp::Sum);
    let world = mpi::tcp_world(n, 46500).unwrap();
    let results = run_world(world, &inputs, ReduceOp::Sum);
    for got in &results {
        assert_eq!(got, &reference);
    }
}

#[test]
fn prop_broadcast_replicates_root() {
    check("ring-broadcast", PropConfig { cases: 30, seed: 0xB04D },
          |rng| {
        let n = gen::usize_in(rng, 2, 8);
        let root = rng.usize_below(n);
        let len = gen::usize_in(rng, 0, 300);
        let payload = gen::f32_vec(rng, len, 5.0);
        let world = mpi::inproc_world(n);
        let results: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = world
                .into_iter()
                .enumerate()
                .map(|(rank, comm)| {
                    let mut buf = if rank == root {
                        payload.clone()
                    } else {
                        Vec::new()
                    };
                    s.spawn(move || {
                        let mut col = Collective::new(&comm);
                        col.broadcast(root, &mut buf).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, got) in results.iter().enumerate() {
            if got != &payload {
                return Err(format!(
                    "rank {rank} missed broadcast (n={n}, root={root}, \
                     len={len})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn repeated_collectives_stay_in_lockstep() {
    // Back-to-back all-reduces must not bleed chunks into each other:
    // per-pair FIFO plus the lockstep schedule keeps rounds separated.
    let n = 4;
    let rounds = 25usize;
    let world = mpi::inproc_world(n);
    let finals: Vec<f32> = std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                s.spawn(move || {
                    let mut col = Collective::new(&comm);
                    let mut acc = 0.0f32;
                    for round in 0..rounds {
                        let mut buf =
                            vec![(rank + round) as f32; 7];
                        col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                        acc += buf[0];
                    }
                    acc
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // sum over ranks of (rank + round) accumulated across rounds
    let expect: f32 = (0..rounds)
        .map(|round| {
            (0..n).map(|rank| (rank + round) as f32).sum::<f32>()
        })
        .sum();
    for got in finals {
        assert_eq!(got, expect);
    }
}
