//! End-to-end tests of the self-tuning topology planner (`--auto`,
//! DESIGN.md §Autotuning): an auto-tuned run must train bitwise-
//! identically to the equivalent hand-flagged run, because every
//! topology the sweep can choose (flat / bucketed / hierarchical, fp32
//! / fp16) is bitwise-equivalent to every other by construction.

use mpi_learn::coordinator::{run_rank, train, Algo, Data,
                             HierarchySpec, Mode, ModelBuilder,
                             TrainConfig, TrainError, Transport};
use mpi_learn::data::GeneratorConfig;
use mpi_learn::mpi::Codec;
use mpi_learn::runtime::Session;

fn base_cfg(auto: bool) -> TrainConfig {
    TrainConfig {
        builder: ModelBuilder::new("mlp", 25),
        algo: Algo {
            mode: Mode::AllReduce,
            batch_size: 25,
            epochs: 2,
            validate_every: 5,
            max_val_batches: 4,
            // Pin the codec axis: the wire format must match between
            // the auto and explicit runs (fp16 rounds the reduced
            // gradients identically on every topology, but differently
            // from fp32).
            compression: Codec::Fp16,
            auto,
            ..Algo::default()
        },
        n_workers: 4,
        seed: 11,
        transport: Transport::Inproc,
        hierarchy: None,
        callbacks: Vec::new(),
    }
}

fn synthetic() -> Data {
    Data::Synthetic {
        gen: GeneratorConfig { seed: 5, ..Default::default() },
        samples_per_worker: 250,
        val_samples: 250,
    }
}

fn weight_bits(r: &mpi_learn::coordinator::TrainResult) -> Vec<u32> {
    r.weights.flat().iter().map(|v| v.to_bits()).collect()
}

/// Acceptance (ISSUE 9): whatever plan the probe-driven sweep picks,
/// the training trajectory is bit-for-bit the trajectory of the same
/// config with the topology pinned by hand — the planner changes the
/// schedule of the collectives, never the arithmetic.
#[test]
fn auto_trains_bitwise_identically_to_the_pinned_topology() {
    let session = Session::native().unwrap();
    let auto = train(&session, &base_cfg(true), &synthetic()).unwrap();
    let flat = train(&session, &base_cfg(false), &synthetic()).unwrap();

    assert_eq!(auto.history.master_updates,
               flat.history.master_updates);
    assert_eq!(weight_bits(&auto), weight_bits(&flat),
               "auto's chosen topology diverged from the flat run");
    assert_eq!(auto.history.validations.len(),
               flat.history.validations.len());
    for (a, f) in auto.history.validations.iter()
        .zip(&flat.history.validations)
    {
        assert_eq!(a.update, f.update);
        assert_eq!(a.val_loss.to_bits(), f.val_loss.to_bits(),
                   "validation at update {} diverged", a.update);
        assert_eq!(a.val_acc.to_bits(), f.val_acc.to_bits());
    }
}

/// `auto` hands the grouping decision to the planner; an explicit
/// hierarchy next to it must error before any world spawns.
#[test]
fn auto_with_an_explicit_hierarchy_is_rejected() {
    let session = Session::native().unwrap();
    let mut cfg = base_cfg(true);
    cfg.hierarchy = Some(HierarchySpec {
        n_groups: 2,
        workers_per_group: 2,
        sync_every: 1,
    });
    match train(&session, &cfg, &synthetic()) {
        Err(TrainError::Config(msg)) => {
            assert!(msg.contains("hierarchy"), "{msg}");
        }
        other => panic!("expected Config error, got {:?}",
                        other.map(|_| ())),
    }
}

/// The planner tunes ring topologies only: auto in a parameter-server
/// mode is a config error, not a silent no-op.
#[test]
fn auto_outside_allreduce_is_rejected() {
    let session = Session::native().unwrap();
    let mut cfg = base_cfg(true);
    cfg.algo.mode = Mode::Downpour { sync: false };
    match train(&session, &cfg, &synthetic()) {
        Err(TrainError::Config(msg)) => {
            assert!(msg.contains("allreduce"), "{msg}");
        }
        other => panic!("expected Config error, got {:?}",
                        other.map(|_| ())),
    }
}

/// SPMD processes derive their role from the same static config before
/// any connection exists, so a rank-0 probe could never reshape the
/// world the other processes committed to — run_rank must reject auto
/// with a clear error instead of hanging.
#[test]
fn run_rank_rejects_auto_with_a_config_error() {
    let session = Session::native().unwrap();
    match run_rank(&session, &base_cfg(true), &synthetic(), 0, 48310) {
        Err(TrainError::Config(msg)) => {
            assert!(msg.contains("run_rank") || msg.contains("SPMD"),
                    "{msg}");
        }
        other => panic!("expected Config error, got {:?}",
                        other.map(|_| ())),
    }
}
