//! End-to-end tests of the hierarchical all-reduce training mode
//! (ISSUE 4): 8 ranks in 2 groups run the ring → tree → ring schedule
//! and finish with bitwise-identical weights on every rank, under both
//! the raw fp32 wire and the fp16 codec; the grouped topology tracks
//! the flat ring numerically and costs no accuracy. Runs on the native
//! CPU backend — no artifacts needed.

use mpi_learn::coordinator::callbacks::Observer;
use mpi_learn::coordinator::worker::RingWorker;
use mpi_learn::coordinator::{train, Algo, Data, Experiment,
                             HierarchySpec, Mode, ModelBuilder,
                             TrainConfig, Transport};
use mpi_learn::data::{generate_shard, DataSet, GeneratorConfig};
use mpi_learn::mpi::{Codec, GroupLayout};
use mpi_learn::runtime::Session;
use mpi_learn::util::rng::Rng;

fn synthetic(samples_per_worker: usize) -> Data {
    Data::Synthetic {
        gen: GeneratorConfig { seed: 5, ..Default::default() },
        samples_per_worker,
        val_samples: 250,
    }
}

fn grouped_cfg(workers: usize, groups: usize, batch: usize,
               epochs: u32, codec: Codec) -> TrainConfig {
    TrainConfig {
        builder: ModelBuilder::new("mlp", batch),
        algo: Algo {
            mode: Mode::AllReduce,
            batch_size: batch,
            epochs,
            validate_every: 0,
            max_val_batches: 4,
            compression: codec,
            ..Algo::default()
        },
        n_workers: workers,
        seed: 11,
        transport: Transport::Inproc,
        hierarchy: Some(HierarchySpec {
            n_groups: groups,
            workers_per_group: workers / groups,
            sync_every: 1,
        }),
        callbacks: Vec::new(),
    }
}

/// Drive `n` RingWorkers directly (the harness of
/// tests/allreduce_train.rs) with an optional group layout; returns
/// each rank's final weights.
fn run_ring_world(n: usize, layout: Option<GroupLayout>, codec: Codec,
                  datasets: &[DataSet])
    -> Vec<mpi_learn::tensor::ParamSet> {
    let session = Session::native().unwrap();
    let exes = session.executables("mlp_b10").unwrap();
    let algo = Algo {
        mode: Mode::AllReduce,
        batch_size: 10,
        epochs: 2,
        compression: codec,
        ..Algo::default()
    };
    let init = exes.init_params(&mut Rng::new(7));
    let world = mpi_learn::mpi::inproc_world(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let ds = &datasets[rank];
                let algo = &algo;
                let exes = exes.clone();
                let layout = layout.clone();
                let init = if rank == 0 { Some(init.clone()) }
                           else { None };
                s.spawn(move || {
                    RingWorker::new(&comm, algo, &exes, ds,
                                    100 + rank as u64, None)
                        .with_groups(layout)
                        .run(init, &mut Observer::disabled())
                        .unwrap()
                        .weights
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

fn make_datasets(n: usize, samples: usize) -> Vec<DataSet> {
    let gen = GeneratorConfig { seed: 21, ..Default::default() };
    let mut rng = Rng::new(3);
    (0..n)
        .map(|_| DataSet::from_shard(generate_shard(&gen, samples,
                                                    &mut rng)))
        .collect()
}

/// ISSUE 4 acceptance: 8-rank, 2-group hierarchical all-reduce trains
/// end-to-end with bitwise-identical weights across all ranks, under
/// the fp32 AND fp16 codecs.
#[test]
fn hier_8rank_2group_weights_bitwise_identical_across_ranks() {
    let datasets = make_datasets(8, 80);
    let layout = GroupLayout::contiguous(8, 2).unwrap();
    for codec in [Codec::Fp32, Codec::Fp16] {
        let weights =
            run_ring_world(8, Some(layout.clone()), codec, &datasets);
        let reference = &weights[0];
        for (rank, w) in weights.iter().enumerate().skip(1) {
            assert_eq!(w, reference,
                       "rank {rank} diverged under {codec:?}");
        }
    }
}

/// The grouped schedule computes the same mean gradient as the flat
/// ring up to float associativity (the bracketing differs: per-group
/// sums combined by the leader tree vs one chain around the world), so
/// the weight trajectories must agree tightly — but NOT bitwise, which
/// no reordered fp32 summation can promise.
#[test]
fn hier_fp32_tracks_flat_ring_fp32() {
    let datasets = make_datasets(8, 80);
    let flat = run_ring_world(8, None, Codec::Fp32, &datasets);
    let layout = GroupLayout::contiguous(8, 2).unwrap();
    let hier =
        run_ring_world(8, Some(layout), Codec::Fp32, &datasets);
    let f = flat[0].flat();
    let h = hier[0].flat();
    assert_eq!(f.len(), h.len());
    let mut worst = 0.0f32;
    for (a, b) in f.iter().zip(h.iter()) {
        worst = worst.max((a - b).abs() / (1.0 + a.abs()));
    }
    assert!(worst < 1e-3,
            "hier drifted {worst} from the flat ring after 16 rounds");
}

/// Full driver path (train() over the WorldPlan): grouped allreduce
/// reaches the same accuracy as the flat ring, and fp16 compression
/// stays within 2 points of fp32 accuracy.
#[test]
fn hier_allreduce_trains_e2e_with_accuracy() {
    let session = Session::native().unwrap();
    let data = synthetic(250);

    let flat = {
        let mut c = grouped_cfg(8, 2, 25, 2, Codec::Fp32);
        c.hierarchy = None;
        train(&session, &c, &data).unwrap()
    };
    let hier = train(&session,
                     &grouped_cfg(8, 2, 25, 2, Codec::Fp32), &data)
        .unwrap();
    let hier16 = train(&session,
                       &grouped_cfg(8, 2, 25, 2, Codec::Fp16), &data)
        .unwrap();

    // 250 samples / batch 25 = 10 rounds per epoch, 2 epochs
    for (name, r) in [("flat", &flat), ("hier", &hier),
                      ("hier+fp16", &hier16)] {
        assert_eq!(r.history.master_updates, 20, "{name}");
        assert_eq!(r.history.workers.len(), 8, "{name}");
    }
    let acc_flat = flat.history.final_val_acc().unwrap();
    let acc_hier = hier.history.final_val_acc().unwrap();
    let acc_16 = hier16.history.final_val_acc().unwrap();
    assert!(acc_hier > 0.6, "hier acc {acc_hier}");
    assert!((acc_hier - acc_flat).abs() <= 0.02,
            "hier {acc_hier} vs flat {acc_flat}");
    assert!((acc_16 - acc_hier).abs() <= 0.02,
            "fp16 {acc_16} vs fp32 {acc_hier}");
}

/// Grouped allreduce runs unchanged over the TCP transport (the
/// collective schedule is transport-independent).
#[test]
fn hier_allreduce_works_over_tcp() {
    let session = Session::native().unwrap();
    let mut c = grouped_cfg(4, 2, 20, 1, Codec::Fp32);
    c.transport = Transport::Tcp { base_port: 46710 };
    let result = train(&session, &c, &synthetic(100)).unwrap();
    assert_eq!(result.history.master_updates, 5);
    assert_eq!(result.history.workers.len(), 4);
}

/// The Experiment facade's grouped-allreduce shorthand drives the same
/// plan (4 groups of 2 exercises a deeper leader tree).
#[test]
fn experiment_grouped_allreduce_end_to_end() {
    let session = Session::native().unwrap();
    let result = Experiment::new("mlp")
        .batch(20)
        .workers(8)
        .allreduce_grouped(4)
        .epochs(1)
        .synthetic(100, 200)
        .max_val_batches(4)
        .run(&session)
        .unwrap();
    assert_eq!(result.history.master_updates, 5);
    assert_eq!(result.history.workers.len(), 8);
}

/// Determinism: two identical grouped runs produce identical weights
/// (the schedule is timing-independent, like the flat ring's).
#[test]
fn hier_allreduce_training_is_deterministic() {
    let session = Session::native().unwrap();
    let cfg = grouped_cfg(4, 2, 20, 1, Codec::Fp16);
    let data = synthetic(100);
    let r1 = train(&session, &cfg, &data).unwrap();
    let r2 = train(&session, &cfg, &data).unwrap();
    assert_eq!(r1.weights, r2.weights);
    assert_eq!(r1.history.master_updates, r2.history.master_updates);
}
