//! Integration tests over the full stack: PJRT runtime + coordinator.
//!
//! These require `make artifacts` to have produced `artifacts/meta.json`;
//! they are skipped (not failed) otherwise so `cargo test` stays green on
//! a fresh checkout.

use std::sync::{Arc, OnceLock};

use mpi_learn::coordinator::{train, train_direct, Algo, Data,
                             HierarchySpec, Mode, ModelBuilder,
                             TrainConfig, Transport};
use mpi_learn::data::GeneratorConfig;
use mpi_learn::optim::OptimizerConfig;
use mpi_learn::runtime::Session;
use mpi_learn::tensor::ParamSet;
use mpi_learn::util::rng::Rng;

fn session() -> Option<&'static Session> {
    static SESSION: OnceLock<Option<Session>> = OnceLock::new();
    SESSION
        .get_or_init(|| {
            let dir = mpi_learn::runtime::default_artifact_dir();
            if dir.join("meta.json").exists() {
                Some(Session::open(&dir).expect("artifacts exist but \
                                                 failed to open"))
            } else {
                eprintln!("SKIP: no artifacts (run `make artifacts`)");
                None
            }
        })
        .as_ref()
}

macro_rules! require_artifacts {
    () => {
        match session() {
            Some(s) => s,
            None => return,
        }
    };
}

fn small_synthetic(samples_per_worker: usize) -> Data {
    Data::Synthetic {
        gen: GeneratorConfig { seed: 7, ..Default::default() },
        samples_per_worker,
        val_samples: 200,
    }
}

fn tiny_cfg(workers: usize) -> TrainConfig {
    TrainConfig {
        builder: ModelBuilder::new("lstm", 10),
        algo: Algo {
            batch_size: 10,
            epochs: 1,
            validate_every: 0,
            max_val_batches: 3,
            ..Algo::default()
        },
        n_workers: workers,
        seed: 1,
        transport: Transport::Inproc,
        hierarchy: None,
        callbacks: Vec::new(),
    }
}

// ---------------------------------------------------------------------------
// runtime
// ---------------------------------------------------------------------------

#[test]
fn grad_step_runs_and_shapes_match() {
    let s = require_artifacts!();
    let exes = s.executables("lstm_b10").unwrap();
    let mut rng = Rng::new(0);
    let params = exes.init_params(&mut rng);
    let x = vec![0.1f32; exes.meta.x_len()];
    let y = vec![1i32; exes.meta.batch];
    let out = exes.grad_step(&params, &x, &y).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert_eq!(out.grads.len(), exes.meta.param_count);
    assert!(out.grads.iter().any(|&g| g != 0.0));
}

#[test]
fn grad_matches_finite_difference() {
    // Directional finite-difference check of the whole compiled fwd/bwd:
    // f(w + eps*d) - f(w - eps*d) ≈ 2 eps <grad, d>.
    let s = require_artifacts!();
    let exes = s.executables("lstm_b10").unwrap();
    let mut rng = Rng::new(3);
    let params = exes.init_params(&mut rng);
    let x: Vec<f32> = (0..exes.meta.x_len())
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let y: Vec<i32> = (0..exes.meta.batch)
        .map(|_| rng.usize_below(3) as i32)
        .collect();
    let out = exes.grad_step(&params, &x, &y).unwrap();
    let dir: Vec<f32> = (0..params.num_params())
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let eps = 1e-3f32;
    let mut plus = params.clone();
    plus.axpy(eps, &dir);
    let mut minus = params.clone();
    minus.axpy(-eps, &dir);
    let (lp, _) = exes.eval_step(&plus, &x, &y).unwrap();
    let (lm, _) = exes.eval_step(&minus, &x, &y).unwrap();
    let fd = (lp - lm) / (2.0 * eps);
    let analytic: f32 = out
        .grads
        .iter()
        .zip(&dir)
        .map(|(g, d)| g * d)
        .sum();
    let denom = fd.abs().max(analytic.abs()).max(1e-3);
    assert!(
        (fd - analytic).abs() / denom < 0.05,
        "fd={fd} analytic={analytic}"
    );
}

#[test]
fn eval_accuracy_in_range() {
    let s = require_artifacts!();
    let exes = s.executables("lstm_b10").unwrap();
    let mut rng = Rng::new(1);
    let params = exes.init_params(&mut rng);
    let x = vec![0.0f32; exes.meta.x_len()];
    let y = vec![0i32; exes.meta.batch];
    let (loss, ncorrect) = exes.eval_step(&params, &x, &y).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=exes.meta.batch as f32).contains(&ncorrect));
}

#[test]
fn predict_logits_shape() {
    let s = require_artifacts!();
    let exes = s.executables("lstm_b10").unwrap();
    let mut rng = Rng::new(2);
    let params = exes.init_params(&mut rng);
    let x = vec![0.5f32; exes.meta.x_len()];
    let logits = exes.predict(&params, &x).unwrap();
    assert_eq!(logits.len(), exes.meta.batch * exes.meta.classes);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn bad_input_sizes_rejected() {
    let s = require_artifacts!();
    let exes = s.executables("lstm_b10").unwrap();
    let mut rng = Rng::new(0);
    let params = exes.init_params(&mut rng);
    let x = vec![0.0f32; 7]; // wrong
    let y = vec![0i32; exes.meta.batch];
    assert!(exes.grad_step(&params, &x, &y).is_err());
    let x = vec![0.0f32; exes.meta.x_len()];
    let y = vec![0i32; 3]; // wrong
    assert!(exes.grad_step(&params, &x, &y).is_err());
}

#[test]
fn concurrent_grad_steps_are_safe_and_deterministic() {
    // Backs the `unsafe impl Sync` on Executable: hammer one compiled
    // executable from many threads and require identical results for
    // identical inputs.
    let s = require_artifacts!();
    let exes = s.executables("lstm_b10").unwrap();
    let mut rng = Rng::new(5);
    let params = Arc::new(exes.init_params(&mut rng));
    let x: Arc<Vec<f32>> = Arc::new(
        (0..exes.meta.x_len()).map(|_| rng.normal_f32(0.0, 1.0))
            .collect());
    let y: Arc<Vec<i32>> = Arc::new(
        (0..exes.meta.batch).map(|_| rng.usize_below(3) as i32)
            .collect());
    let reference = exes.grad_step(&params, &x, &y).unwrap();
    std::thread::scope(|scope| {
        for _ in 0..8 {
            let exes = exes.clone();
            let params = params.clone();
            let x = x.clone();
            let y = y.clone();
            let ref_loss = reference.loss;
            let ref_grads = reference.grads.clone();
            scope.spawn(move || {
                for _ in 0..16 {
                    let out = exes.grad_step(&params, &x, &y).unwrap();
                    assert_eq!(out.loss, ref_loss);
                    assert_eq!(out.grads, ref_grads);
                }
            });
        }
    });
}

// ---------------------------------------------------------------------------
// training sessions
// ---------------------------------------------------------------------------

#[test]
fn async_downpour_trains_to_high_accuracy() {
    let s = require_artifacts!();
    let mut cfg = tiny_cfg(2);
    cfg.algo.epochs = 2;
    let result = train(s, &cfg, &small_synthetic(300)).unwrap();
    let acc = result.history.final_val_acc().unwrap();
    assert!(acc > 0.9, "final val acc {acc}");
    assert!(result.history.master_updates >= 2 * 2 * 30);
}

#[test]
fn sync_downpour_round_counting() {
    let s = require_artifacts!();
    let mut cfg = tiny_cfg(3);
    cfg.algo.mode = Mode::Downpour { sync: true };
    let result = train(s, &cfg, &small_synthetic(100)).unwrap();
    // 3 workers x 10 batches each, barrier of 3 -> exactly 10 rounds
    assert_eq!(result.history.master_updates, 10);
}

#[test]
fn easgd_trains() {
    let s = require_artifacts!();
    let mut cfg = tiny_cfg(2);
    cfg.algo.epochs = 3;
    cfg.algo.mode = Mode::Easgd {
        tau: 5,
        alpha: 0.5,
        worker_optimizer: OptimizerConfig::Momentum {
            lr: 0.05, momentum: 0.9, nesterov: false },
    };
    let result = train(s, &cfg, &small_synthetic(300)).unwrap();
    let acc = result.history.final_val_acc().unwrap();
    assert!(acc > 0.8, "easgd final val acc {acc}");
}

#[test]
fn hierarchical_two_groups() {
    let s = require_artifacts!();
    let mut cfg = tiny_cfg(4);
    cfg.hierarchy = Some(HierarchySpec {
        n_groups: 2,
        workers_per_group: 2,
        sync_every: 5,
    });
    cfg.algo.epochs = 2;
    let result = train(s, &cfg, &small_synthetic(200)).unwrap();
    let acc = result.history.final_val_acc().unwrap();
    assert!(acc > 0.8, "hierarchical final val acc {acc}");
    // super-master sees one AggGradients per group sync
    assert!(result.history.master_updates > 0);
}

#[test]
fn tcp_transport_trains() {
    let s = require_artifacts!();
    let mut cfg = tiny_cfg(2);
    cfg.transport = Transport::Tcp { base_port: 47300 };
    let result = train(s, &cfg, &small_synthetic(100)).unwrap();
    assert!(result.history.master_updates >= 20);
}

#[test]
fn direct_baseline_trains() {
    let s = require_artifacts!();
    let mut cfg = tiny_cfg(1);
    cfg.algo.epochs = 2;
    let result = train_direct(s, &cfg, &small_synthetic(300)).unwrap();
    let acc = result.history.final_val_acc().unwrap();
    assert!(acc > 0.9, "direct final val acc {acc}");
}

#[test]
fn single_worker_matches_direct_loss_trajectory() {
    // mpi_learn-with-1-worker vs Keras-alone (§V): same data, same seeds
    // -> statistically indistinguishable training. We check both reach
    // high accuracy and similar final loss.
    let s = require_artifacts!();
    let mut cfg = tiny_cfg(1);
    cfg.algo.epochs = 2;
    let data = small_synthetic(300);
    let dist = train(s, &cfg, &data).unwrap();
    let direct = train_direct(s, &cfg, &data).unwrap();
    let a = dist.history.validations.last().unwrap();
    let b = direct.history.validations.last().unwrap();
    assert!((a.val_acc - b.val_acc).abs() < 0.1,
            "dist {} vs direct {}", a.val_acc, b.val_acc);
}

#[test]
fn validation_schedule_produces_records() {
    let s = require_artifacts!();
    let mut cfg = tiny_cfg(2);
    cfg.algo.validate_every = 10;
    cfg.algo.epochs = 1;
    let result = train(s, &cfg, &small_synthetic(200)).unwrap();
    // 2 workers x 20 batches = 40 updates -> ~4 scheduled + 1 final
    assert!(result.history.validations.len() >= 4,
            "got {}", result.history.validations.len());
}

#[test]
fn training_is_deterministic_for_sync_single_worker() {
    // Full determinism holds when there's no async interleaving:
    // one worker, fixed seeds -> identical final weights.
    let s = require_artifacts!();
    let cfg = tiny_cfg(1);
    let data = small_synthetic(100);
    let r1 = train(s, &cfg, &data).unwrap();
    let r2 = train(s, &cfg, &data).unwrap();
    assert_eq!(r1.weights, r2.weights);
}

#[test]
fn checkpoint_roundtrip_through_training() {
    let s = require_artifacts!();
    let cfg = tiny_cfg(1);
    let result = train(s, &cfg, &small_synthetic(100)).unwrap();
    let path = std::env::temp_dir().join("mpi_learn_integration_ckpt.bin");
    result.weights.save(&path).unwrap();
    let loaded = ParamSet::load(&path).unwrap();
    assert_eq!(loaded, result.weights);
}

#[test]
fn staleness_tracks_worker_count() {
    // The Fig 2 mechanism: with W async workers interleaving, mean
    // gradient staleness approaches W-1 (each gradient is based on
    // weights from ~W-1 updates ago).
    let s = require_artifacts!();
    let data = small_synthetic(200);
    let mut cfg = tiny_cfg(4);
    cfg.algo.epochs = 2;
    let r = train(s, &cfg, &data).unwrap();
    assert!(r.history.staleness_mean > 1.0,
            "4 workers should produce staleness >1, got {}",
            r.history.staleness_mean);
    let r1 = train(s, &tiny_cfg(1), &data).unwrap();
    assert_eq!(r1.history.staleness_mean, 0.0,
               "single worker is never stale");
}

#[test]
fn spmd_run_rank_over_tcp_mesh() {
    // The mpirun-style deployment path: every rank its own endpoint
    // (threads here; `mpi-learn launch` runs the same code in separate
    // OS processes).
    let s = require_artifacts!();
    let mut cfg = tiny_cfg(2);
    cfg.algo.epochs = 1;
    let data = small_synthetic(100);
    let result = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for rank in 1..=2 {
            let cfg = &cfg;
            let data = &data;
            handles.push(scope.spawn(move || {
                mpi_learn::coordinator::run_rank(s, cfg, data, rank,
                                                 47800)
            }));
        }
        let master = mpi_learn::coordinator::run_rank(s, &cfg, &data, 0,
                                                      47800);
        for h in handles {
            assert!(h.join().unwrap().unwrap().is_none());
        }
        master
    })
    .unwrap()
    .expect("rank 0 returns the result");
    assert_eq!(result.history.master_updates, 20);
}

#[test]
fn job_config_end_to_end() {
    // config-file driven training: JSON -> JobConfig -> train
    let s = require_artifacts!();
    let job = mpi_learn::coordinator::JobConfig::from_json_text(
        r#"{
            "model": "lstm", "batch": 10, "workers": 2,
            "algo": {"epochs": 1, "max_val_batches": 2,
                     "optimizer": {"kind": "sgd", "lr": 0.05}},
            "data": {"synthetic": {"samples_per_worker": 100,
                                   "val_samples": 100}}
        }"#)
        .unwrap();
    let r = train(s, &job.train, &job.data).unwrap();
    assert_eq!(r.history.master_updates, 20);
    assert!(r.history.final_val_acc().is_some());
}

#[test]
fn more_workers_do_more_updates_per_wallclock() {
    // Weak-scaling sanity: with per-worker data fixed, total master
    // updates scale with worker count.
    let s = require_artifacts!();
    let data = small_synthetic(100); // 10 batches per worker
    let r1 = train(s, &tiny_cfg(1), &data).unwrap();
    let r4 = train(s, &tiny_cfg(4), &data).unwrap();
    assert_eq!(r1.history.master_updates, 10);
    assert_eq!(r4.history.master_updates, 40);
}
