//! End-to-end tests of wire-level gradient compression (mpi::codec)
//! across the training modes, on the native CPU backend.
//!
//! Key invariants:
//! - `Mode::AllReduce` keeps bitwise-identical weights on every rank
//!   under every codec (the all-gather replicates owner-compressed
//!   payloads verbatim);
//! - top-k with error feedback stays within 2% validation accuracy of
//!   fp32 on the quickstart problem;
//! - the PS paths (Downpour, EASGD, hierarchy) train end-to-end with a
//!   codec configured, and the compressed public-API path works via
//!   `Experiment::compression`.

use mpi_learn::coordinator::callbacks::Observer;
use mpi_learn::coordinator::worker::RingWorker;
use mpi_learn::coordinator::{train, Algo, Data, Experiment,
                             HierarchySpec, Mode, ModelBuilder,
                             TrainConfig, Transport};
use mpi_learn::data::{generate_shard, DataSet, GeneratorConfig};
use mpi_learn::mpi::Codec;
use mpi_learn::runtime::Session;
use mpi_learn::util::rng::Rng;

fn allreduce_cfg(workers: usize, batch: usize, epochs: u32,
                 compression: Codec) -> TrainConfig {
    TrainConfig {
        builder: ModelBuilder::new("mlp", batch),
        algo: Algo {
            mode: Mode::AllReduce,
            batch_size: batch,
            epochs,
            max_val_batches: 4,
            compression,
            ..Algo::default()
        },
        n_workers: workers,
        seed: 11,
        transport: Transport::Inproc,
        hierarchy: None,
        callbacks: Vec::new(),
    }
}

fn synthetic(samples_per_worker: usize) -> Data {
    Data::Synthetic {
        gen: GeneratorConfig { seed: 5, ..Default::default() },
        samples_per_worker,
        val_samples: 250,
    }
}

/// Run the raw RingWorker on `n` ranks with the given codec and return
/// every rank's final weights.
fn ring_weights(codec: Codec, n: usize)
    -> Vec<mpi_learn::tensor::ParamSet> {
    let session = Session::native().unwrap();
    let exes = session.executables("mlp_b10").unwrap();
    let algo = Algo {
        mode: Mode::AllReduce,
        batch_size: 10,
        epochs: 2,
        compression: codec,
        ..Algo::default()
    };
    let gen = GeneratorConfig { seed: 21, ..Default::default() };
    let mut rng = Rng::new(3);
    let datasets: Vec<DataSet> = (0..n)
        .map(|_| DataSet::from_shard(generate_shard(&gen, 80, &mut rng)))
        .collect();
    let init = exes.init_params(&mut Rng::new(7));

    let world = mpi_learn::mpi::inproc_world(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let ds = &datasets[rank];
                let algo = &algo;
                let exes = exes.clone();
                let init = if rank == 0 { Some(init.clone()) }
                           else { None };
                s.spawn(move || {
                    RingWorker::new(&comm, algo, &exes, ds,
                                    100 + rank as u64, None)
                        .run(init, &mut Observer::disabled())
                        .unwrap()
                        .weights
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn fp16_allreduce_ranks_end_bitwise_identical() {
    // Satellite (ISSUE 3): 4 ranks under fp16 compression still finish
    // with bitwise-identical weights.
    let weights = ring_weights(Codec::Fp16, 4);
    let reference = &weights[0];
    for (rank, w) in weights.iter().enumerate().skip(1) {
        assert_eq!(w, reference, "rank {rank} diverged under fp16");
    }
    // and fp16 training actually moved somewhere close to fp32
    let raw = ring_weights(Codec::Fp32, 4);
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for (a, b) in reference.flat().iter().zip(raw[0].flat()) {
        num += (f64::from(*a) - f64::from(*b)).powi(2);
        den += f64::from(*b).powi(2);
    }
    let rel = (num / den).sqrt();
    assert!(rel < 0.15,
            "fp16 weights drifted {rel:.4} relative from fp32");
}

#[test]
fn topk_allreduce_ranks_end_bitwise_identical() {
    let weights = ring_weights(Codec::TopK { k: 0.1 }, 4);
    let reference = &weights[0];
    for (rank, w) in weights.iter().enumerate().skip(1) {
        assert_eq!(w, reference, "rank {rank} diverged under topk");
    }
}

#[test]
fn topk_with_error_feedback_tracks_fp32_accuracy() {
    // Satellite (ISSUE 3): top-k (k = 10%) with error feedback reaches
    // accuracy within 2% of fp32 on the quickstart problem.
    let session = Session::native().unwrap();
    let data = synthetic(250);
    let fp32 = train(&session,
                     &allreduce_cfg(4, 25, 4, Codec::Fp32), &data)
        .unwrap();
    let topk = train(&session,
                     &allreduce_cfg(4, 25, 4, Codec::TopK { k: 0.1 }),
                     &data)
        .unwrap();
    let acc_fp32 = fp32.history.final_val_acc().expect("fp32 val");
    let acc_topk = topk.history.final_val_acc().expect("topk val");
    assert!(acc_fp32 > 0.6, "fp32 baseline failed to train: {acc_fp32}");
    assert!(acc_topk >= acc_fp32 - 0.02,
            "topk acc {acc_topk} fell > 2% below fp32 acc {acc_fp32}");
}

#[test]
fn fp16_allreduce_end_to_end_over_both_transports() {
    let session = Session::native().unwrap();
    let result = train(&session, &allreduce_cfg(4, 25, 2, Codec::Fp16),
                       &synthetic(250))
        .unwrap();
    assert_eq!(result.history.master_updates, 20);
    let acc = result.history.final_val_acc().expect("final validation");
    assert!(acc > 0.6, "fp16 allreduce final val acc {acc}");

    let mut cfg = allreduce_cfg(3, 20, 1, Codec::Fp16);
    cfg.transport = Transport::Tcp { base_port: 46750 };
    let result = train(&session, &cfg, &synthetic(100)).unwrap();
    assert_eq!(result.history.master_updates, 5);
}

#[test]
fn downpour_trains_under_fp16_and_topk() {
    // PS path: compressed gradient uplink (error feedback) + fp16
    // weight downlink; topk leaves the downlink raw.
    let session = Session::native().unwrap();
    for codec in [Codec::Fp16, Codec::TopK { k: 0.25 }] {
        let cfg = TrainConfig {
            builder: ModelBuilder::new("mlp", 20),
            algo: Algo {
                batch_size: 20,
                epochs: 2,
                max_val_batches: 4,
                compression: codec,
                ..Algo::default()
            },
            n_workers: 2,
            seed: 13,
            transport: Transport::Inproc,
            hierarchy: None,
            callbacks: Vec::new(),
        };
        let result = train(&session, &cfg, &synthetic(200)).unwrap();
        assert_eq!(result.history.master_updates, 2 * 2 * 10,
                   "{codec:?}");
        let acc = result.history.final_val_acc().expect("validation");
        assert!(acc > 0.6, "downpour {codec:?} final val acc {acc}");
    }
}

#[test]
fn sync_downpour_and_easgd_train_under_fp16() {
    let session = Session::native().unwrap();
    let mut cfg = TrainConfig {
        builder: ModelBuilder::new("mlp", 20),
        algo: Algo {
            mode: Mode::Downpour { sync: true },
            batch_size: 20,
            epochs: 2,
            max_val_batches: 4,
            compression: Codec::Fp16,
            ..Algo::default()
        },
        n_workers: 2,
        seed: 13,
        transport: Transport::Inproc,
        hierarchy: None,
        callbacks: Vec::new(),
    };
    let result = train(&session, &cfg, &synthetic(200)).unwrap();
    assert!(result.history.master_updates > 0);
    let acc = result.history.final_val_acc().expect("validation");
    assert!(acc > 0.6, "sync downpour fp16 final val acc {acc}");

    cfg.algo.mode = Mode::Easgd {
        tau: 5,
        alpha: 0.5,
        worker_optimizer:
            mpi_learn::optim::OptimizerConfig::Sgd { lr: 0.05 },
    };
    let result = train(&session, &cfg, &synthetic(200)).unwrap();
    assert!(result.history.master_updates > 0,
            "easgd fp16 made no exchanges");
}

#[test]
fn hierarchy_trains_under_fp16() {
    let session = Session::native().unwrap();
    let cfg = TrainConfig {
        builder: ModelBuilder::new("mlp", 20),
        algo: Algo {
            batch_size: 20,
            epochs: 1,
            max_val_batches: 4,
            compression: Codec::Fp16,
            ..Algo::default()
        },
        n_workers: 4,
        seed: 17,
        transport: Transport::Inproc,
        hierarchy: Some(HierarchySpec {
            n_groups: 2,
            workers_per_group: 2,
            sync_every: 3,
        }),
        callbacks: Vec::new(),
    };
    let result = train(&session, &cfg, &synthetic(100)).unwrap();
    assert!(result.history.master_updates > 0,
            "hierarchical fp16 synced nothing upward");
}

#[test]
fn experiment_facade_carries_compression() {
    // The compressed public-API path (quickstart's --compression flag
    // maps exactly onto this chain).
    let session = Session::native().unwrap();
    let result = Experiment::new("mlp")
        .batch(25)
        .workers(4)
        .epochs(1)
        .allreduce()
        .compression(Codec::Fp16)
        .synthetic(100, 100)
        .max_val_batches(4)
        .run(&session)
        .unwrap();
    assert_eq!(result.history.master_updates, 4);
    assert!(result.history.final_val_acc().is_some());
}
