//! Failure-injection tests: corrupt inputs, protocol violations, peer
//! disconnects, early termination. The framework must fail loudly on bad
//! data and degrade gracefully on bad peers — the failure modes a
//! supercomputing batch job actually hits.

use std::time::Duration;

use mpi_learn::data::{DataSet, GeneratorConfig, Shard};
use mpi_learn::mpi::{self, Payload, Tag};
use mpi_learn::util::rng::Rng;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("mpi_learn_failure_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

// ---------------------------------------------------------------------------
// data corruption
// ---------------------------------------------------------------------------

#[test]
fn dataset_load_fails_on_corrupt_member() {
    let cfg = GeneratorConfig { seq_len: 4, features: 2,
                                ..Default::default() };
    let mut rng = Rng::new(1);
    let good = mpi_learn::data::generate_shard(&cfg, 10, &mut rng);
    let p_good = tmp("good.mpil");
    let p_bad = tmp("bad.mpil");
    good.write(&p_good).unwrap();
    good.write(&p_bad).unwrap();
    // flip one payload byte in the second file
    let mut bytes = std::fs::read(&p_bad).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&p_bad, &bytes).unwrap();
    let err = DataSet::from_files(&[p_good, p_bad]);
    assert!(err.is_err(), "corruption must not load silently");
}

#[test]
#[should_panic(expected = "mixed seq_len")]
fn dataset_load_panics_on_mixed_schemas() {
    let mut rng = Rng::new(2);
    let a = mpi_learn::data::generate_shard(
        &GeneratorConfig { seq_len: 4, features: 2,
                           ..Default::default() }, 5, &mut rng);
    let b = mpi_learn::data::generate_shard(
        &GeneratorConfig { seq_len: 6, features: 2,
                           ..Default::default() }, 5, &mut rng);
    let pa = tmp("schema_a.mpil");
    let pb = tmp("schema_b.mpil");
    a.write(&pa).unwrap();
    b.write(&pb).unwrap();
    let _ = DataSet::from_files(&[pa, pb]);
}

#[test]
fn shard_zero_samples_roundtrips() {
    // degenerate but legal: empty shard
    let shard = Shard { seq_len: 3, features: 2, classes: 3,
                        labels: vec![], x: vec![] };
    let p = tmp("empty.mpil");
    shard.write(&p).unwrap();
    let back = Shard::read(&p).unwrap();
    assert_eq!(back.n_samples(), 0);
}

// ---------------------------------------------------------------------------
// protocol violations
// ---------------------------------------------------------------------------

#[test]
fn master_like_loop_survives_junk_tags() {
    // A rogue peer sends nonsense; a serving loop keyed on tags must be
    // able to skip it and keep handling real traffic.
    let mut world = mpi::inproc_world(3);
    let c2 = world.pop().unwrap();
    let c1 = world.pop().unwrap();
    let c0 = world.pop().unwrap();

    let rogue = std::thread::spawn(move || {
        for _ in 0..5 {
            c1.send(0, Tag::Ping, Payload::Empty).unwrap();
        }
        c1.send(0, Tag::Gradients, Payload::Empty).unwrap(); // wrong body
    });
    let honest = std::thread::spawn(move || {
        c2.send(0, Tag::Gradients,
                Payload::grad(0, 1.0, vec![0.5; 16])).unwrap();
    });

    let mut real_grads = 0;
    let mut junk = 0;
    for _ in 0..7 {
        let env = c0.recv().unwrap();
        match (env.tag, &env.payload) {
            (Tag::Gradients, Payload::Grad { .. }) => real_grads += 1,
            _ => junk += 1,
        }
    }
    assert_eq!(real_grads, 1);
    assert_eq!(junk, 6);
    rogue.join().unwrap();
    honest.join().unwrap();
}

#[test]
fn recv_after_all_senders_dropped_errors() {
    let mut world = mpi::inproc_world(2);
    let c1 = world.pop().unwrap();
    let c0 = world.pop().unwrap();
    drop(c0);
    // all senders to rank 1 are gone -> disconnect, not hang
    match c1.recv() {
        Err(mpi::CommError::Disconnected) => {}
        other => panic!("expected Disconnected, got {other:?}"),
    }
}

#[test]
fn tcp_send_to_closed_peer_fails_gracefully() {
    let base_port = 46900;
    let mut world = mpi::tcp_world(2, base_port).unwrap();
    let c1 = world.pop().unwrap();
    let c0 = world.pop().unwrap();
    drop(c1);
    // allow the OS to tear down the sockets
    std::thread::sleep(Duration::from_millis(50));
    // the first send may land in a kernel buffer; repeated sends must
    // eventually error rather than panic
    let mut failed = false;
    for _ in 0..200 {
        if c0
            .send(1, Tag::Weights, Payload::floats(0, vec![0.0; 65_536]))
            .is_err()
        {
            failed = true;
            break;
        }
    }
    assert!(failed, "sends to a dead TCP peer should eventually fail");
}

#[test]
fn tcp_dead_peer_is_purged_after_failed_send() {
    // Regression (ISSUE 8 bugfix): a failed send used to leave the dead
    // peer's half-open stream in the sender map, so every later send
    // re-entered write_all against a broken socket (and on some kernels
    // blocked in the TCP retransmit queue). The transport must tear the
    // endpoint down on first failure: has_peer() goes false and further
    // sends fail fast with SendFailed.
    let base_port = 46940; // 46900 belongs to the test above
    let mut world = mpi::tcp_world(2, base_port).unwrap();
    let c1 = world.pop().unwrap();
    let c0 = world.pop().unwrap();
    drop(c1);
    std::thread::sleep(Duration::from_millis(50));
    assert!(c0.has_peer(1), "peer map intact before any failure");
    let mut failed_at = None;
    for i in 0..200 {
        if c0
            .send(1, Tag::Weights, Payload::floats(0, vec![0.0; 65_536]))
            .is_err()
        {
            failed_at = Some(i);
            break;
        }
    }
    assert!(failed_at.is_some(), "sends to a dead peer must fail");
    // the half-open endpoint is gone...
    assert!(!c0.has_peer(1), "dead peer must be purged from the map");
    // ...and the next send fails immediately without touching a socket
    match c0.send(1, Tag::Ping, Payload::Empty) {
        Err(mpi::CommError::SendFailed(1)) => {}
        other => panic!("expected fast SendFailed(1), got {other:?}"),
    }
}

#[test]
fn inproc_close_peer_mirrors_a_dead_rank() {
    // close_peer() is how the elastic layer evicts a departed rank; the
    // in-process transport must behave like the TCP one afterwards.
    let mut world = mpi::inproc_world(3);
    let _c2 = world.pop().unwrap();
    let _c1 = world.pop().unwrap();
    let c0 = world.pop().unwrap();
    assert!(c0.has_peer(1) && c0.has_peer(2));
    c0.close_peer(1);
    assert!(!c0.has_peer(1), "closed peer must disappear");
    assert!(c0.has_peer(2), "other peers are untouched");
    match c0.send(1, Tag::Ping, Payload::Empty) {
        Err(mpi::CommError::SendFailed(1)) => {}
        other => panic!("expected SendFailed(1), got {other:?}"),
    }
    // closing twice is a no-op, and self is never a peer
    c0.close_peer(1);
    assert!(!c0.has_peer(0), "self-channel is not a peer");
}

#[test]
fn wire_decode_never_panics_on_fuzz() {
    let mut rng = Rng::new(99);
    for _ in 0..2000 {
        let len = rng.usize_below(256);
        let buf: Vec<u8> =
            (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = mpi_learn::mpi::message::decode(&buf); // must not panic
    }
}

// ---------------------------------------------------------------------------
// early termination
// ---------------------------------------------------------------------------

#[test]
fn worker_stops_cleanly_on_exit_message() {
    // A fake master: handshake, then answer the first gradient with Exit.
    // The worker must wind down and still deliver its stats + Exit.
    let dir = mpi_learn::runtime::default_artifact_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let session = mpi_learn::runtime::Session::open(&dir).unwrap();
    let exes = session.executables("lstm_b10").unwrap();

    let mut world = mpi::inproc_world(2);
    let wcomm = world.pop().unwrap();
    let mcomm = world.pop().unwrap();

    let algo = mpi_learn::coordinator::Algo {
        batch_size: 10,
        epochs: 50, // would run long if Exit were ignored
        ..Default::default()
    };
    let gen = GeneratorConfig::default();
    let mut rng = Rng::new(3);
    let ds = DataSet::from_shard(mpi_learn::data::generate_shard(
        &gen, 100, &mut rng));

    let exes2 = exes.clone();
    let algo2 = algo.clone();
    let worker = std::thread::spawn(move || {
        mpi_learn::coordinator::worker::Worker::new(
            &wcomm, 0, &algo2, &exes2, &ds, 1).run()
    });

    // fake master
    let n = exes.meta.param_count;
    let env = mcomm.recv().unwrap();
    assert_eq!(env.tag, Tag::Ready);
    mcomm.send(1, Tag::Weights, Payload::floats(0, vec![0.0; n]))
        .unwrap();
    let env = mcomm.recv().unwrap();
    assert_eq!(env.tag, Tag::Gradients);
    mcomm.send(1, Tag::Exit, Payload::Empty).unwrap();

    // worker should wrap up: TrainStats then Exit
    let mut tags = Vec::new();
    for _ in 0..2 {
        tags.push(mcomm.recv().unwrap().tag);
    }
    assert_eq!(tags, vec![Tag::TrainStats, Tag::Exit]);
    let report = worker.join().unwrap().unwrap();
    assert!(report.batches <= 1);
}
