//! End-to-end bitwise equivalence of the pooled compute engine: the
//! same training run at `--threads` 1, 2, and 4 must end with the
//! exact same weight bytes, under both the fp32 and fp16 wire codecs.
//! The kernels, gate activations, optimizer steps, and codec loops
//! only ever split index ranges across the pool — never a per-element
//! operation order — so thread count can never change a result
//! (DESIGN.md §Compute kernels).

use mpi_learn::coordinator::{train, Algo, Data, Mode, ModelBuilder,
                             TrainConfig, Transport};
use mpi_learn::data::GeneratorConfig;
use mpi_learn::mpi::Codec;
use mpi_learn::runtime::Session;
use mpi_learn::tensor::ParamSet;

fn cfg(model: &str, batch: usize, threads: usize, codec: Codec)
    -> TrainConfig {
    TrainConfig {
        builder: ModelBuilder::new(model, batch),
        algo: Algo {
            mode: Mode::AllReduce,
            batch_size: batch,
            epochs: 2,
            validate_every: 0,
            max_val_batches: 4,
            compression: codec,
            threads,
            ..Algo::default()
        },
        n_workers: 3,
        seed: 17,
        transport: Transport::Inproc,
        hierarchy: None,
        callbacks: Vec::new(),
    }
}

fn synthetic(samples_per_worker: usize) -> Data {
    Data::Synthetic {
        gen: GeneratorConfig { seed: 5, ..Default::default() },
        samples_per_worker,
        val_samples: 100,
    }
}

/// Train the same configuration at each thread count, each on a fresh
/// session (so no pool sizing leaks between runs), and return the
/// final weights per count.
fn weights_per_thread_count(model: &str, batch: usize, codec: Codec,
                            counts: &[usize]) -> Vec<ParamSet> {
    counts
        .iter()
        .map(|&t| {
            let session = Session::native().unwrap();
            let cfg = cfg(model, batch, t, codec);
            train(&session, &cfg, &synthetic(5 * batch))
                .unwrap()
                .weights
        })
        .collect()
}

#[test]
fn training_is_bitwise_identical_across_thread_counts_fp32() {
    let all = weights_per_thread_count("mlp", 20, Codec::Fp32,
                                       &[1, 2, 4]);
    assert_eq!(all[0], all[1], "threads=2 diverged from threads=1");
    assert_eq!(all[0], all[2], "threads=4 diverged from threads=1");
}

#[test]
fn training_is_bitwise_identical_across_thread_counts_fp16() {
    // fp16 runs the pooled pack/unpack + fused decode-reduce path on
    // every all-reduce hop; the pool must not perturb a single bit.
    let all = weights_per_thread_count("mlp", 20, Codec::Fp16,
                                       &[1, 2, 4]);
    assert_eq!(all[0], all[1], "threads=2 diverged from threads=1");
    assert_eq!(all[0], all[2], "threads=4 diverged from threads=1");
}

#[test]
fn lstm_training_is_bitwise_identical_across_thread_counts() {
    // The LSTM path additionally exercises the pooled gate-activation
    // loops (sigmoid/tanh over the 4-gate block).
    let all = weights_per_thread_count("lstm", 10, Codec::Fp32,
                                       &[1, 4]);
    assert_eq!(all[0], all[1], "threads=4 diverged from threads=1");
}

#[test]
fn auto_thread_count_matches_serial_training() {
    // threads = 0 (the default) auto-sizes from available_parallelism;
    // whatever it picks, the result must equal the serial run.
    let all = weights_per_thread_count("mlp", 20, Codec::Fp32, &[1, 0]);
    assert_eq!(all[0], all[1], "auto thread count diverged from serial");
}
