//! End-to-end tests of the bucketed, compute-overlapped all-reduce
//! (ISSUE 6): with `Algo::buckets` the single per-round collective
//! becomes one windowed collective per layer bucket, each launched
//! mid-backprop as its layer's gradient lands, plus a tail bucket for
//! the piggybacked loss + stop flag.
//!
//! Acceptance invariants:
//! - bucketed training produces **bitwise-identical weights to the
//!   monolithic path** under fp32 and fp16 (per-bucket windows reuse
//!   the global chunk boundaries, so every fp16 packet holds the exact
//!   same elements either way);
//! - every rank still ends bitwise-identical under all three codecs
//!   (fp32 / fp16 / top-k — top-k reshuffles its per-chunk selection
//!   across bucket windows, so it only promises cross-rank identity);
//! - the hierarchical (grouped) topology composes with buckets;
//! - early stopping stays lockstep: the flag rides the tail bucket and
//!   all ranks abandon the flagged round together.
//!
//! Runs on the native CPU backend — no artifacts needed.

use mpi_learn::coordinator::callbacks::{Callback, CallbackSet, Control,
                                        Observer, RoundInfo};
use mpi_learn::coordinator::worker::RingWorker;
use mpi_learn::coordinator::{Algo, Experiment, Mode};
use mpi_learn::data::{generate_shard, DataSet, GeneratorConfig};
use mpi_learn::mpi::{Codec, GroupLayout};
use mpi_learn::runtime::Session;
use mpi_learn::tensor::ParamSet;
use mpi_learn::util::rng::Rng;

fn make_datasets(n: usize, samples: usize) -> Vec<DataSet> {
    let gen = GeneratorConfig { seed: 21, ..Default::default() };
    let mut rng = Rng::new(3);
    (0..n)
        .map(|_| DataSet::from_shard(generate_shard(&gen, samples,
                                                    &mut rng)))
        .collect()
}

/// Rank-0 callback that requests a stop after a fixed update count —
/// deterministic stand-in for EarlyStopping's validation trigger.
struct StopAt(u64);

impl Callback for StopAt {
    fn on_round(&mut self, info: &RoundInfo<'_>, ctl: &mut Control) {
        if info.update >= self.0 {
            ctl.stop();
        }
    }
}

/// Drive `n` RingWorkers over the inproc transport and return every
/// rank's (final weights, batches run). With `stop_at`, rank 0 runs a
/// [`StopAt`] callback; other ranks always get `Observer::disabled()`.
fn run_ring_world(model_key: &str, n: usize, buckets: bool,
                  codec: Codec, layout: Option<GroupLayout>,
                  epochs: u32, datasets: &[DataSet],
                  stop_at: Option<u64>)
    -> Vec<(ParamSet, u64)> {
    let session = Session::native().unwrap();
    let exes = session.executables(model_key).unwrap();
    let algo = Algo {
        mode: Mode::AllReduce,
        batch_size: 10,
        epochs,
        compression: codec,
        buckets,
        ..Algo::default()
    };
    let init = exes.init_params(&mut Rng::new(7));
    let world = mpi_learn::mpi::inproc_world(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .enumerate()
            .map(|(rank, comm)| {
                let ds = &datasets[rank];
                let algo = &algo;
                let exes = exes.clone();
                let layout = layout.clone();
                let init = if rank == 0 { Some(init.clone()) }
                           else { None };
                s.spawn(move || {
                    let mut obs = match stop_at {
                        Some(at) if rank == 0 => {
                            let mut cbs = CallbackSet::new();
                            cbs.push(Box::new(StopAt(at)));
                            Observer::new(algo, None, cbs)
                        }
                        _ => Observer::disabled(),
                    };
                    let outcome =
                        RingWorker::new(&comm, algo, &exes, ds,
                                        100 + rank as u64, None)
                            .with_groups(layout)
                            .run(init, &mut obs)
                            .unwrap();
                    (outcome.weights, outcome.report.batches)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

fn weights_only(model_key: &str, n: usize, buckets: bool, codec: Codec,
                layout: Option<GroupLayout>, datasets: &[DataSet])
    -> Vec<ParamSet> {
    run_ring_world(model_key, n, buckets, codec, layout, 2, datasets,
                   None)
        .into_iter()
        .map(|(w, _)| w)
        .collect()
}

/// ISSUE 6 acceptance: the bucketed all-reduce produces
/// bitwise-identical replicated weights to the monolithic path under
/// fp32 AND fp16 — every bucket's wire packets reuse the global chunk
/// boundaries, so the codec sees the exact same element groups.
#[test]
fn bucketed_matches_monolithic_bitwise_under_fp32_and_fp16() {
    for model in ["mlp_b10", "lstm_b10"] {
        let datasets = make_datasets(4, 80);
        for codec in [Codec::Fp32, Codec::Fp16] {
            let mono =
                weights_only(model, 4, false, codec, None, &datasets);
            let bucketed =
                weights_only(model, 4, true, codec, None, &datasets);
            assert_eq!(bucketed[0], mono[0],
                       "{model}: bucketed diverged from monolithic \
                        under {codec:?}");
            for (rank, w) in bucketed.iter().enumerate().skip(1) {
                assert_eq!(w, &bucketed[0],
                           "{model}: rank {rank} diverged under \
                            {codec:?} (bucketed)");
            }
        }
    }
}

/// Top-k re-selects per wire window, so the bucketed trajectory is a
/// *different* (equally valid) sparsification than the monolithic one —
/// but the replicated-optimizer invariant must still hold: every rank
/// bitwise-identical.
#[test]
fn bucketed_topk_ranks_end_bitwise_identical() {
    let datasets = make_datasets(4, 80);
    let weights = weights_only("mlp_b10", 4, true,
                               Codec::TopK { k: 0.1 }, None, &datasets);
    let init = {
        let session = Session::native().unwrap();
        let exes = session.executables("mlp_b10").unwrap();
        exes.init_params(&mut Rng::new(7))
    };
    assert_ne!(weights[0], init, "training must have moved the weights");
    for (rank, w) in weights.iter().enumerate().skip(1) {
        assert_eq!(w, &weights[0],
                   "rank {rank} diverged under topk (bucketed)");
    }
}

/// Buckets compose with the hierarchical (grouped) topology of ISSUE 4:
/// each bucket runs the ring → tree → ring schedule over its window.
/// fp32 and fp16 stay bitwise-equal to the grouped monolithic run; all
/// three codecs keep cross-rank identity.
#[test]
fn bucketed_composes_with_hierarchical_groups() {
    let datasets = make_datasets(8, 80);
    let layout = GroupLayout::contiguous(8, 2).unwrap();
    for codec in [Codec::Fp32, Codec::Fp16] {
        let mono = weights_only("mlp_b10", 8, false, codec,
                                Some(layout.clone()), &datasets);
        let bucketed = weights_only("mlp_b10", 8, true, codec,
                                    Some(layout.clone()), &datasets);
        assert_eq!(bucketed[0], mono[0],
                   "grouped bucketed diverged from grouped monolithic \
                    under {codec:?}");
        for (rank, w) in bucketed.iter().enumerate().skip(1) {
            assert_eq!(w, &bucketed[0],
                       "rank {rank} diverged under {codec:?} \
                        (grouped bucketed)");
        }
    }
    let topk = weights_only("mlp_b10", 8, true, Codec::TopK { k: 0.1 },
                            Some(layout), &datasets);
    for (rank, w) in topk.iter().enumerate().skip(1) {
        assert_eq!(w, &topk[0],
                   "rank {rank} diverged under topk (grouped bucketed)");
    }
}

/// Early-stop lockstep under buckets: the stop flag rides the tail
/// bucket, so when rank 0's callbacks request a stop every rank
/// abandons the flagged round pre-update and finishes with the same
/// batch count and bitwise-identical weights.
#[test]
fn bucketed_early_stop_keeps_ranks_lockstep() {
    let datasets = make_datasets(4, 80);
    let out = run_ring_world("mlp_b10", 4, true, Codec::Fp16, None, 2,
                             &datasets, Some(3));
    // 80 samples / batch 10 = 8 rounds/epoch × 2 epochs = 16 possible;
    // the flag raised after update 3 kills round 4 on every rank.
    for (rank, (_, batches)) in out.iter().enumerate() {
        assert_eq!(*batches, 3,
                   "rank {rank} did not stop in lockstep at update 3");
    }
    for (rank, (w, _)) in out.iter().enumerate().skip(1) {
        assert_eq!(w, &out[0].0,
                   "rank {rank} diverged after the early stop");
    }
}

/// The public-API path: `Experiment::buckets()` (the quickstart's
/// `--buckets` flag maps onto this chain) trains end-to-end.
#[test]
fn experiment_facade_carries_buckets() {
    let session = Session::native().unwrap();
    let result = Experiment::new("mlp")
        .batch(25)
        .workers(4)
        .epochs(1)
        .allreduce()
        .buckets()
        .synthetic(100, 100)
        .max_val_batches(4)
        .run(&session)
        .unwrap();
    assert_eq!(result.history.master_updates, 4);
    assert!(result.history.final_val_acc().is_some());
}
