//! Property-based tests over coordinator/substrate invariants (custom
//! `util::prop` driver — no proptest crate offline).
//!
//! Focus: routing (file division), batching, wire encoding, parameter
//! state, and simulator conservation laws — the invariants the training
//! protocol's correctness rests on.

use std::path::PathBuf;

use mpi_learn::data::loader::{divide_files, division_is_partition};
use mpi_learn::data::{generate_shard, DataSet, GeneratorConfig};
use mpi_learn::mpi::codec::Codec;
use mpi_learn::mpi::message::{decode, encode, Payload, Tag, WorkerStats};
use mpi_learn::simulator::{simulate_async, simulate_sync, CostModel,
                           SimConfig};
use mpi_learn::tensor::ParamSet;
use mpi_learn::util::json::Json;
use mpi_learn::util::prop::{check, gen, PropConfig};
use mpi_learn::util::rng::Rng;

fn cases(n: usize) -> PropConfig {
    PropConfig { cases: n, seed: 0xD15C0 }
}

#[test]
fn prop_file_division_is_balanced_partition() {
    check("file-division", cases(200), |rng| {
        let n_files = gen::usize_in(rng, 1, 200);
        let n_workers = gen::usize_in(rng, 1, 64);
        let paths: Vec<PathBuf> = (0..n_files)
            .map(|i| PathBuf::from(format!("shard_{i}")))
            .collect();
        if !division_is_partition(&paths, n_workers) {
            return Err(format!(
                "not a partition: {n_files} files, {n_workers} workers"));
        }
        let sizes: Vec<usize> = (0..n_workers)
            .map(|w| divide_files(&paths, w, n_workers).len())
            .collect();
        let (min, max) = (sizes.iter().min().unwrap(),
                          sizes.iter().max().unwrap());
        if max - min > 1 {
            return Err(format!("unbalanced: {sizes:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_wire_roundtrip_random_payloads() {
    check("wire-roundtrip", cases(300), |rng| {
        let tag = match rng.usize_below(5) {
            0 => Tag::Ready,
            1 => Tag::Gradients,
            2 => Tag::Weights,
            3 => Tag::ExchangeWeights,
            _ => Tag::TrainStats,
        };
        let payload = match rng.usize_below(4) {
            0 => Payload::Empty,
            1 => {
                let step = rng.next_u64();
                let len = gen::usize_in(rng, 0, 5000);
                let data = gen::f32_vec(rng, len, 10.0);
                Payload::floats(step, data)
            }
            2 => {
                let step = rng.next_u64();
                let loss = rng.normal_f32(0.0, 5.0);
                let len = gen::usize_in(rng, 0, 5000);
                let data = gen::f32_vec(rng, len, 1.0);
                Payload::grad(step, loss, data)
            }
            _ => Payload::Stats(WorkerStats {
                epoch: rng.next_u64() as u32,
                batches_done: rng.next_u64() >> 8,
                samples_done: rng.next_u64() >> 8,
                train_loss: rng.normal_f32(1.0, 2.0),
                grad_time_s: rng.uniform() * 100.0,
                comm_wait_s: rng.uniform() * 10.0,
            }),
        };
        let buf = encode(tag, &payload);
        if buf.len() != payload.nbytes() {
            return Err("nbytes mismatch".into());
        }
        let (t2, p2) = decode(&buf).map_err(|e| e.to_string())?;
        if t2 != tag || p2 != payload {
            return Err("roundtrip mismatch".into());
        }
        // truncation must never panic, only error
        let cut = rng.usize_below(buf.len().max(1));
        let _ = decode(&buf[..cut]);
        Ok(())
    });
}

/// Satellite (ISSUE 3): every float-carrying payload round-trips the
/// wire through all three codecs — including empty, odd-length, and
/// NaN/Inf-bearing buffers. NaN breaks `PartialEq`, so the property is
/// byte-level idempotence: re-encoding the decoded payload must
/// reproduce the exact frame.
#[test]
fn prop_codec_wire_roundtrip_edge_buffers() {
    check("codec-wire-roundtrip", cases(300), |rng| {
        // deliberately include the edge lengths every time lengths
        // are drawn small
        let len = match rng.usize_below(6) {
            0 => 0,
            1 => 1,
            2 => gen::usize_in(rng, 3, 9) | 1, // odd
            _ => gen::usize_in(rng, 2, 2000),
        };
        let mut data = gen::f32_vec(rng, len, 100.0);
        // sprinkle non-finite values and halves-exact values
        for v in data.iter_mut() {
            match rng.usize_below(12) {
                0 => *v = f32::NAN,
                1 => *v = f32::INFINITY,
                2 => *v = f32::NEG_INFINITY,
                3 => *v = 0.0,
                4 => *v = 1e9,  // overflows fp16 -> Inf
                5 => *v = 1e-9, // underflows fp16 -> 0
                _ => {}
            }
        }
        let codecs = [
            Codec::Fp32,
            Codec::Fp16,
            Codec::TopK { k: 0.1 },
            Codec::TopK { k: 1.0 },
        ];
        for codec in codecs {
            let step = rng.next_u64();
            let loss = rng.normal_f32(0.0, 5.0);
            let payload = match codec.pack(&data) {
                Some(p) => Payload::packed(step, loss, p),
                None => Payload::grad(step, loss, data.clone()),
            };
            let buf = encode(Tag::Gradients, &payload);
            if buf.len() != payload.nbytes() {
                return Err(format!("{codec:?}: nbytes mismatch"));
            }
            let (tag, decoded) =
                decode(&buf).map_err(|e| e.to_string())?;
            if tag != Tag::Gradients {
                return Err("tag changed".into());
            }
            // byte-level idempotence survives NaN payloads
            if encode(tag, &decoded) != buf {
                return Err(format!(
                    "{codec:?}: re-encode of the decoded payload \
                     diverged (len {len})"));
            }
            // the dense view must carry the packed semantics: same
            // length, and exact values wherever the codec is exact
            let (_, _, dense) = decoded
                .grad_like()
                .ok_or("decoded payload lost its gradient view")?;
            if dense.len() != len {
                return Err(format!("{codec:?}: length changed"));
            }
            if matches!(codec, Codec::Fp32 | Codec::TopK { .. }) {
                // kept values are exact f32 in these codecs
                let reference = match codec.pack(&data) {
                    Some(p) => p.unpack(),
                    None => data.clone(),
                };
                let same = dense.iter().zip(&reference).all(|(a, b)| {
                    a.to_bits() == b.to_bits()
                        || (a.is_nan() && b.is_nan())
                });
                if !same {
                    return Err(format!("{codec:?}: values changed"));
                }
            }
            // truncation must never panic, only error
            let cut = rng.usize_below(buf.len().max(1));
            let _ = decode(&buf[..cut]);
        }
        Ok(())
    });
}

#[test]
fn prop_paramset_checkpoint_roundtrip() {
    let dir = std::env::temp_dir().join("mpi_learn_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let mut case_id = 0u64;
    check("paramset-roundtrip", cases(40), |rng| {
        case_id += 1;
        let n_tensors = gen::usize_in(rng, 1, 8);
        let specs: Vec<(String, Vec<usize>)> = (0..n_tensors)
            .map(|i| {
                let ndim = gen::usize_in(rng, 1, 3);
                let shape: Vec<usize> =
                    (0..ndim).map(|_| gen::usize_in(rng, 1, 24)).collect();
                (format!("p{i}"), shape)
            })
            .collect();
        let mut ps = ParamSet::glorot_init(&specs, rng);
        // randomize biases too
        for v in ps.flat_mut() {
            *v += rng.normal_f32(0.0, 0.1);
        }
        let path = dir.join(format!("ckpt_{case_id}.bin"));
        ps.save(&path).map_err(|e| e.to_string())?;
        let loaded = ParamSet::load(&path).map_err(|e| e.to_string())?;
        if loaded != ps {
            return Err("checkpoint roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_batching_covers_every_sample_at_most_once() {
    check("batching", cases(60), |rng| {
        let n = gen::usize_in(rng, 10, 400);
        let batch = gen::usize_in(rng, 1, n);
        let gen_cfg = GeneratorConfig {
            seq_len: gen::usize_in(rng, 1, 6),
            features: gen::usize_in(rng, 1, 5),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let mut grng = Rng::new(gen_cfg.seed);
        let ds = DataSet::from_shard(generate_shard(&gen_cfg, n,
                                                    &mut grng));
        let mut seen = 0usize;
        let mut brng = rng.fork(1);
        ds.for_each_batch(batch, &mut brng, |x, y| {
            if x.len() != batch * gen_cfg.seq_len * gen_cfg.features {
                panic!("bad x len");
            }
            seen += y.len();
        });
        let expect = (n / batch) * batch;
        if seen != expect {
            return Err(format!("saw {seen}, expected {expect}"));
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_conservation_laws() {
    check("simulator-laws", cases(80), |rng| {
        let n_params = gen::usize_in(rng, 100, 100_000);
        let mut cost = CostModel::cluster(n_params);
        cost.jitter = rng.uniform() * 0.3;
        cost.t_val = rng.uniform() * 0.01;
        let cfg = SimConfig {
            n_workers: gen::usize_in(rng, 1, 64),
            total_samples: gen::usize_in(rng, 1000, 100_000) as u64,
            batch: [10, 100, 500][rng.usize_below(3)],
            epochs: gen::usize_in(rng, 1, 4) as u32,
            validate_every: [0, 10, 100][rng.usize_below(3)] as u64,
            sync: false,
        };
        let seed = rng.next_u64();
        let r = simulate_async(&cost, &cfg, seed);
        let expected_updates =
            cfg.batches_per_worker() * cfg.n_workers as u64;
        if r.updates != expected_updates {
            return Err(format!("updates {} != {expected_updates}",
                               r.updates));
        }
        if r.master_busy_s > r.total_time_s + 1e-9 {
            return Err("master busier than wallclock".into());
        }
        if !(0.0..=1.0 + 1e-9).contains(&r.master_utilization) {
            return Err(format!("utilization {}", r.master_utilization));
        }
        // master can't beat its own service rate
        let floor = r.updates as f64 * cost.t_update;
        if r.total_time_s < floor - 1e-9 {
            return Err("faster than master service floor".into());
        }
        let rs = simulate_sync(&cost, &cfg, seed);
        if rs.updates != cfg.batches_per_worker() {
            return Err("sync round count wrong".into());
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_speedup_bounded_by_workers() {
    check("speedup-bound", cases(30), |rng| {
        let mut cost = CostModel::shared_memory(3023);
        cost.jitter = 0.0; // deterministic for a strict bound
        let w = gen::usize_in(rng, 1, 32);
        // keep total work identical across worker counts (no remainder
        // batches dropped), else the bound is confounded
        let base = SimConfig {
            n_workers: 1,
            total_samples: (w * 100 * gen::usize_in(rng, 5, 40)) as u64,
            batch: 100,
            epochs: 1,
            validate_every: 0,
            sync: false,
        };
        let t1 = simulate_async(&cost, &base, 0).total_time_s;
        let tw = simulate_async(
            &cost, &SimConfig { n_workers: w, ..base.clone() }, 0)
            .total_time_s;
        let speedup = t1 / tw;
        if speedup > w as f64 + 1e-6 {
            return Err(format!("superlinear: {speedup} at {w}"));
        }
        if speedup < 0.9 {
            return Err(format!("sublinear below 1: {speedup}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.usize_below(4) }
              else { rng.usize_below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => {
                let len = rng.usize_below(12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.usize_below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            '\\'
                        }
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.usize_below(5))
                .map(|_| random_json(rng, depth - 1))
                .collect()),
            _ => Json::Obj((0..rng.usize_below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect()),
        }
    }
    check("json-roundtrip", cases(200), |rng| {
        let j = random_json(rng, 3);
        for text in [j.to_string_compact(), j.to_string_pretty()] {
            let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
            if parsed != j {
                return Err(format!("roundtrip mismatch: {text}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_optimizer_state_dimensions_stable() {
    use mpi_learn::optim::OptimizerConfig;
    check("optimizer-dims", cases(50), |rng| {
        let n = gen::usize_in(rng, 1, 4096);
        let cfgs = [
            OptimizerConfig::Sgd { lr: 0.01 },
            OptimizerConfig::Momentum { lr: 0.01, momentum: 0.9,
                                        nesterov: false },
            OptimizerConfig::Adam { lr: 0.01, beta1: 0.9, beta2: 0.999,
                                    eps: 1e-8 },
        ];
        let mut w = gen::f32_vec(rng, n, 1.0);
        let g = gen::f32_vec(rng, n, 1.0);
        for cfg in cfgs {
            let mut opt = cfg.build(n);
            let before = w.clone();
            opt.update(&mut w, &g);
            if w.len() != n {
                return Err("dimension changed".into());
            }
            if w == before && g.iter().any(|&x| x != 0.0) {
                return Err(format!("{} made no progress", opt.name()));
            }
            if w.iter().any(|x| !x.is_finite()) {
                return Err(format!("{} produced non-finite", opt.name()));
            }
        }
        Ok(())
    });
}
