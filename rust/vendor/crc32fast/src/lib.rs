//! Minimal vendored IEEE CRC-32 (reflected, polynomial 0xEDB88320) with
//! the `crc32fast::Hasher` API surface used by the shard file format.
//! Table-driven single-byte implementation — plenty for shard-sized
//! payloads; drop-in replaceable by the upstream SIMD crate.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC-32 hasher.
#[derive(Clone, Debug)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: 0xFFFF_FFFF }
    }

    /// Resume from a previously finalized checksum.
    pub fn new_with_initial(init: u32) -> Hasher {
        Hasher { state: init ^ 0xFFFF_FFFF }
    }

    pub fn update(&mut self, buf: &[u8]) {
        let mut s = self.state;
        for &b in buf {
            s = TABLE[((s ^ b as u32) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }

    pub fn reset(&mut self) {
        self.state = 0xFFFF_FFFF;
    }
}

/// One-shot convenience (upstream `crc32fast::hash`).
pub fn hash(buf: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(buf);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0u16..1024).map(|i| (i % 251) as u8).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), hash(&data));
    }

    #[test]
    fn corruption_changes_checksum() {
        let mut data = vec![7u8; 64];
        let base = hash(&data);
        data[40] ^= 0x01;
        assert_ne!(hash(&data), base);
    }
}
