//! Minimal vendored implementation of the `log` crate facade.
//!
//! Implements exactly the API surface this repository uses (see
//! `vendor/README.md`): the five level macros, `Level`/`LevelFilter`,
//! the `Log` trait with `Metadata`/`Record`, and the global
//! `set_logger`/`set_max_level`/`max_level` plumbing. Drop-in
//! replaceable by the upstream crate.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single log record.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Global maximum-verbosity filter.
#[repr(usize)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter)
        -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record (level + target module path).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the pre-formatted message arguments.
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging sink. Implementors must be thread-safe: records arrive from
/// any thread.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }

    fn log(&self, _: &Record) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Returned by [`set_logger`] if a logger was already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already set")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger, or a no-op sink if none was set.
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

/// Set the global maximum level filter.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global maximum level filter.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro/dispatch backend — not part of the public facade.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    let record = Record {
        metadata: Metadata { level, target },
        args,
    };
    logger().log(&record);
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_log(lvl, module_path!(),
                                  format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+))
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+))
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+))
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+))
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    struct Counter {
        hits: AtomicUsize,
    }

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }

        fn log(&self, record: &Record) {
            if self.enabled(record.metadata()) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                let _ = format!("{}", record.args());
            }
        }

        fn flush(&self) {}
    }

    #[test]
    fn levels_compare_against_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Trace > LevelFilter::Off);
    }

    // One shared logger for every test in this binary: the global
    // LOGGER is process-wide and first-set-wins.
    static COUNTER: Counter = Counter { hits: AtomicUsize::new(0) };

    #[test]
    fn filter_gates_macro_dispatch() {
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        let before = COUNTER.hits.load(Ordering::Relaxed);
        info!("visible {}", 1);
        debug!("invisible {}", 2);
        let after = COUNTER.hits.load(Ordering::Relaxed);
        assert_eq!(after - before, 1);
    }

    #[test]
    fn second_set_logger_errors() {
        let _ = set_logger(&COUNTER);
        assert!(set_logger(&COUNTER).is_err());
    }
}
