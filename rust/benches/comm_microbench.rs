//! Communication-substrate microbench: latency and throughput of the two
//! transports for protocol-sized messages (weight/gradient payloads).
//!
//!     cargo bench --bench comm_microbench

use mpi_learn::mpi::{self, Payload, Tag};
use mpi_learn::util::bench::{fmt_secs, print_table, write_csv};
use mpi_learn::util::stats;

fn pingpong(make: impl Fn() -> Vec<mpi::Comm>, floats: usize,
            reps: usize) -> (f64, f64) {
    let mut world = make();
    let c1 = world.pop().unwrap();
    let c0 = world.pop().unwrap();
    let data = vec![0.5f32; floats];
    let echo = std::thread::spawn(move || {
        for _ in 0..reps {
            let env = c1.recv().unwrap();
            c1.send(0, Tag::Weights, env.payload).unwrap();
        }
    });
    // warm
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        c0.send(1, Tag::Gradients, Payload::floats(0, data.clone()))
            .unwrap();
        let _ = c0.recv().unwrap();
        samples.push(t0.elapsed().as_secs_f64() / 2.0); // one-way
    }
    echo.join().unwrap();
    (stats::percentile(&samples, 50.0), stats::percentile(&samples, 95.0))
}

fn main() {
    // paper-relevant sizes: LSTM benchmark (3k params), MLP (33k),
    // transformer (800k)
    let sizes = [(3_023usize, "lstm"), (32_963, "mlp"),
                 (798_467, "transformer")];
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut port = 48100u16;
    for (floats, tag) in sizes {
        let reps = if floats > 100_000 { 50 } else { 200 };
        let (inp_p50, inp_p95) =
            pingpong(|| mpi::inproc_world(2), floats, reps);
        let (tcp_p50, tcp_p95) = pingpong(
            || mpi::tcp_world(2, port).unwrap(), floats, reps);
        port += 10;
        let bytes = (floats * 4 + 28) as f64;
        rows.push(vec![
            format!("{tag} ({floats} f32)"),
            fmt_secs(inp_p50),
            fmt_secs(inp_p95),
            fmt_secs(tcp_p50),
            fmt_secs(tcp_p95),
            format!("{:.2}", bytes / tcp_p50 / 1e9),
        ]);
        csv.push(vec![
            tag.to_string(),
            format!("{floats}"),
            format!("{inp_p50:.3e}"),
            format!("{tcp_p50:.3e}"),
        ]);
    }
    print_table(
        "one-way message time (weight/gradient payloads)",
        &["payload", "inproc p50", "inproc p95", "tcp p50", "tcp p95",
          "tcp GB/s"],
        &rows,
    );
    write_csv("runs/bench/comm_microbench.csv",
              &["payload", "floats", "inproc_p50_s", "tcp_p50_s"],
              &csv).unwrap();
    println!("\ninproc ≈ the paper's shared-memory server; tcp ≈ its \
              cluster interconnect path.\nThese feed \
              CostModel::{{latency, bandwidth}}.");
}
