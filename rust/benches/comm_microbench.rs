//! Communication-substrate microbench: latency and throughput of the two
//! transports for protocol-sized messages (weight/gradient payloads),
//! plus the wire volume of one compressed ring all-reduce round per
//! codec — the number the CI bench-smoke job gates on.
//!
//!     cargo bench --bench comm_microbench
//!     cargo bench --bench comm_microbench -- --ci --json BENCH_ci.json
//!
//! `--ci` runs a reduced configuration (small payloads, few reps);
//! `--json <path>` writes a machine-readable summary including
//! `ratio_fp16` and `ratio_topk10` (compressed / raw wire bytes per
//! all-reduce round), which CI requires to be < 0.6 and < 0.25.

use std::collections::BTreeMap;

use mpi_learn::mpi::collective::{Collective, ReduceOp};
use mpi_learn::mpi::{self, Codec, Payload, Tag};
use mpi_learn::util::bench::{fmt_secs, print_table, write_csv,
                             write_json};
use mpi_learn::util::cli::Args;
use mpi_learn::util::json::Json;
use mpi_learn::util::stats;

fn pingpong(make: impl Fn() -> Vec<mpi::Comm>, floats: usize,
            reps: usize) -> (f64, f64) {
    let mut world = make();
    let c1 = world.pop().unwrap();
    let c0 = world.pop().unwrap();
    let data = vec![0.5f32; floats];
    let echo = std::thread::spawn(move || {
        for _ in 0..reps {
            let env = c1.recv().unwrap();
            c1.send(0, Tag::Weights, env.payload).unwrap();
        }
    });
    // warm
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        c0.send(1, Tag::Gradients, Payload::floats(0, data.clone()))
            .unwrap();
        let _ = c0.recv().unwrap();
        samples.push(t0.elapsed().as_secs_f64() / 2.0); // one-way
    }
    echo.join().unwrap();
    (stats::percentile(&samples, 50.0), stats::percentile(&samples, 95.0))
}

/// One rank's wire bytes and time per all-reduce round under `codec`
/// (inproc world; bytes use the exact encoded payload sizes). Each
/// rank times only its measured rounds — thread spawn and the warmup
/// round (which also allocates the error-feedback residual) are
/// excluded; the lockstep collective makes the per-rank maximum the
/// wall time.
fn allreduce_wire(n: usize, floats: usize, rounds: usize, codec: Codec)
    -> (f64, f64) {
    let world = mpi::inproc_world(n);
    let per_rank: Vec<(u64, f64)> = std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .map(|comm| {
                s.spawn(move || {
                    let mut col = Collective::new(&comm);
                    col.set_codec(codec);
                    col.set_exact_tail(2);
                    let mut buf = vec![0.001f32; floats];
                    col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                    let before = comm.bytes_sent();
                    let t0 = std::time::Instant::now();
                    for i in 0..rounds {
                        for (j, v) in buf.iter_mut().enumerate() {
                            *v = ((i + j) % 23) as f32 * 1e-3;
                        }
                        col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                    }
                    (comm.bytes_sent() - before,
                     t0.elapsed().as_secs_f64())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let secs = per_rank
        .iter()
        .map(|(_, t)| *t)
        .fold(0.0f64, f64::max)
        / rounds as f64;
    let bytes = per_rank.iter().map(|(b, _)| *b).sum::<u64>() as f64
        / (rounds * n) as f64;
    (bytes, secs)
}

fn main() {
    let args = Args::from_env();
    let ci = args.bool("ci");
    let json_path = args.str("json", "runs/bench/comm_microbench.json");
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }

    // ---- transport pingpong ----
    // paper-relevant sizes: LSTM benchmark (3k params), MLP (33k),
    // transformer (800k); CI keeps the two small ones
    let sizes: &[(usize, &str)] = if ci {
        &[(3_023, "lstm"), (32_963, "mlp")]
    } else {
        &[(3_023, "lstm"), (32_963, "mlp"), (798_467, "transformer")]
    };
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut port = 48100u16;
    for &(floats, tag) in sizes {
        let reps = match (ci, floats > 100_000) {
            (true, _) => 20,
            (false, true) => 50,
            (false, false) => 200,
        };
        let (inp_p50, inp_p95) =
            pingpong(|| mpi::inproc_world(2), floats, reps);
        let (tcp_p50, tcp_p95) = pingpong(
            || mpi::tcp_world(2, port).unwrap(), floats, reps);
        port += 10;
        let bytes = (floats * 4 + 28) as f64;
        rows.push(vec![
            format!("{tag} ({floats} f32)"),
            fmt_secs(inp_p50),
            fmt_secs(inp_p95),
            fmt_secs(tcp_p50),
            fmt_secs(tcp_p95),
            format!("{:.2}", bytes / tcp_p50 / 1e9),
        ]);
        csv.push(vec![
            tag.to_string(),
            format!("{floats}"),
            format!("{inp_p50:.3e}"),
            format!("{tcp_p50:.3e}"),
        ]);
    }
    print_table(
        "one-way message time (weight/gradient payloads)",
        &["payload", "inproc p50", "inproc p95", "tcp p50", "tcp p95",
          "tcp GB/s"],
        &rows,
    );
    write_csv("runs/bench/comm_microbench.csv",
              &["payload", "floats", "inproc_p50_s", "tcp_p50_s"],
              &csv).unwrap();

    // ---- compressed all-reduce wire volume ----
    // gradient-sized buffer + the 2 piggybacked control elements the
    // training loop actually ships
    let (world_n, floats, rounds) = if ci {
        (4usize, 32_963usize + 2, 10usize)
    } else {
        (4, 32_963 + 2, 40)
    };
    let codecs = [
        ("fp32", Codec::Fp32),
        ("fp16", Codec::Fp16),
        ("topk10", Codec::TopK { k: 0.1 }),
    ];
    let mut rows = Vec::new();
    let mut bytes_by_codec: BTreeMap<String, f64> = BTreeMap::new();
    for (name, codec) in codecs {
        let (bytes, secs) = allreduce_wire(world_n, floats, rounds,
                                           codec);
        bytes_by_codec.insert(name.to_string(), bytes);
        rows.push(vec![
            name.to_string(),
            format!("{bytes:.0}"),
            format!("{:.3}", bytes / bytes_by_codec["fp32"]),
            fmt_secs(secs),
        ]);
    }
    print_table(
        &format!("ring all-reduce wire volume per rank per round \
                  ({floats} f32, {world_n} ranks)"),
        &["codec", "bytes/round", "vs fp32", "time/round"],
        &rows,
    );
    let ratio_fp16 = bytes_by_codec["fp16"] / bytes_by_codec["fp32"];
    let ratio_topk10 = bytes_by_codec["topk10"] / bytes_by_codec["fp32"];
    println!("\nfp16 ships {:.1}% of the raw bytes, topk:0.1 ships \
              {:.1}% — the CI gate requires < 60% and < 25%.",
             100.0 * ratio_fp16, 100.0 * ratio_topk10);

    // ---- wire encoding: fresh allocation vs reused buffer ----
    // The TCP transport keeps a per-connection frame-buffer pool and
    // encodes every steady-state send with `encode_into` (exact-sized
    // by `Payload::nbytes`, zero reallocation); this prices what that
    // pool removes relative to a fresh `encode` Vec per message.
    let mut rows = Vec::new();
    let mut encode_delta: BTreeMap<String, f64> = BTreeMap::new();
    for &(floats, tag) in sizes {
        let reps = if ci { 200 } else { 2_000 };
        let payload =
            mpi::Payload::floats(7, vec![0.125f32; floats]);
        let fresh = mpi_learn::util::bench::measure(
            "encode", 10, reps,
            || {
                std::hint::black_box(
                    mpi::message::encode(Tag::Gradients, &payload));
            });
        let mut buf = Vec::new();
        let reused = mpi_learn::util::bench::measure(
            "encode_into", 10, reps,
            || {
                mpi::message::encode_into(&mut buf, Tag::Gradients,
                                          &payload);
                std::hint::black_box(&buf);
            });
        let saved =
            100.0 * (fresh.mean_s - reused.mean_s) / fresh.mean_s;
        encode_delta.insert(tag.to_string(), saved);
        rows.push(vec![
            format!("{tag} ({floats} f32)"),
            fmt_secs(fresh.mean_s),
            fmt_secs(reused.mean_s),
            format!("{saved:.1}%"),
        ]);
    }
    print_table(
        "wire encoding: fresh Vec per message vs pooled reused buffer",
        &["payload", "encode (alloc)", "encode_into (reuse)",
          "reuse saves"],
        &rows,
    );

    let summary: BTreeMap<String, Json> = [
        ("bench".to_string(),
         Json::Str("comm_microbench".to_string())),
        ("ci".to_string(), Json::Bool(ci)),
        ("world".to_string(), Json::Num(world_n as f64)),
        ("floats".to_string(), Json::Num(floats as f64)),
        ("allreduce_bytes_per_round".to_string(),
         Json::Obj(bytes_by_codec
             .iter()
             .map(|(k, v)| (k.clone(), Json::Num(*v)))
             .collect())),
        ("ratio_fp16".to_string(), Json::Num(ratio_fp16)),
        ("ratio_topk10".to_string(), Json::Num(ratio_topk10)),
        ("encode_reuse_saved_pct".to_string(),
         Json::Obj(encode_delta
             .iter()
             .map(|(k, v)| (k.clone(), Json::Num(*v)))
             .collect())),
    ]
    .into_iter()
    .collect();
    write_json(&json_path, &Json::Obj(summary)).unwrap();
    println!("wrote {json_path}");
}
