//! Serving-path benchmark: latency and throughput of the full HTTP →
//! micro-batcher → executor stack, measured end-to-end against a live
//! `serving::start` instance on an ephemeral port.
//!
//!     cargo bench --bench serve_bench
//!     cargo bench --bench serve_bench -- --ci
//!     cargo bench --bench serve_bench -- --ci --pr-json ../BENCH_pr.json
//!
//! Measured numbers (machine-dependent) go to
//! `runs/bench/serve_bench.json`. The committed BENCH_pr.json gets the
//! deterministic closed-form `serving` block instead
//! ([`mpi_learn::serving::bench_block`] — the same function
//! `allreduce_scaling --pr-json` embeds), so `--pr-json` here is an
//! idempotent merge and CI can regenerate + `git diff` the file on any
//! machine.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use mpi_learn::runtime::Session;
use mpi_learn::serving::http::client_request;
use mpi_learn::serving::{self, ServeConfig, SERVE_BENCH_BATCHES,
                         SERVE_BENCH_REPLICAS};
use mpi_learn::util::bench::{fmt_secs, print_table, write_json};
use mpi_learn::util::cli::Args;
use mpi_learn::util::json::Json;
use mpi_learn::util::rng::Rng;
use mpi_learn::util::stats;

const MODEL: &str = "mlp";

fn checkpoint_dir() -> PathBuf {
    let dir = std::env::temp_dir().join("mpi_learn_serve_bench");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let exe = Session::native()
        .unwrap()
        .executables(&format!("{MODEL}_b32"))
        .unwrap();
    exe.init_params(&mut Rng::new(2017))
        .save(&dir.join("checkpoint-1.mplw"))
        .unwrap();
    dir
}

fn body_for(rows: usize, row_len: usize) -> String {
    let row: Vec<String> = (0..row_len)
        .map(|k| format!("{:?}", ((k % 89) as f64) * 0.02 - 0.9))
        .collect();
    let row = format!("[{}]", row.join(","));
    format!("{{\"instances\": [{}]}}", vec![row; rows].join(","))
}

fn main() {
    let args = Args::from_env();
    let ci = args.bool("ci");
    let json_path = args.str("json", "runs/bench/serve_bench.json");
    let pr_json = args.str_opt("pr-json");
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    let iters = if ci { 30 } else { 200 };
    let clients = 4usize;

    let exe = Session::native()
        .unwrap()
        .executables(&format!("{MODEL}_b32"))
        .unwrap();
    let row_len = exe.meta.seq_len * exe.meta.features;
    let dir = checkpoint_dir();

    let mut rows_out = Vec::new();
    let mut measured: BTreeMap<String, Json> = BTreeMap::new();
    for &replicas in &SERVE_BENCH_REPLICAS {
        let cfg = ServeConfig {
            model: MODEL.into(),
            checkpoint_dir: dir.clone(),
            port: 0,
            max_batch: 32,
            batch_deadline_ms: 1,
            replicas,
            tcp: false,
            base_port: 47950,
            poll_ms: 10_000,
            replica_timeout_ms: 10_000,
            threads: 1,
        };
        let mut handle = serving::start(&cfg).unwrap();
        let addr = handle.addr();
        for &batch in &SERVE_BENCH_BATCHES {
            let body = Arc::new(body_for(batch, row_len));
            // Latency: sequential closed-loop round trips.
            let mut samples = Vec::with_capacity(iters);
            for i in 0..iters + 3 {
                let t0 = Instant::now();
                let (status, _) = client_request(
                    addr, "POST", "/v1/predict", &body).unwrap();
                assert_eq!(status, 200);
                if i >= 3 {
                    samples.push(t0.elapsed().as_secs_f64());
                }
            }
            let p50 = stats::percentile(&samples, 50.0);
            let p99 = stats::percentile(&samples, 99.0);
            // Throughput: open the loop with concurrent clients so
            // replica fan-out actually pipelines.
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for _ in 0..clients {
                    let body = body.clone();
                    s.spawn(move || {
                        for _ in 0..iters {
                            let (status, _) = client_request(
                                addr, "POST", "/v1/predict", &body)
                                .unwrap();
                            assert_eq!(status, 200);
                        }
                    });
                }
            });
            let wall = t0.elapsed().as_secs_f64();
            let qps = (clients * iters * batch) as f64 / wall;
            let key = format!("b{batch}_r{replicas}");
            rows_out.push(vec![
                format!("{replicas}"),
                format!("{batch}"),
                fmt_secs(p50),
                fmt_secs(p99),
                format!("{qps:.0}"),
            ]);
            measured.insert(format!("p50_ns/{key}"),
                            Json::Num((p50 * 1e9).round()));
            measured.insert(format!("p99_ns/{key}"),
                            Json::Num((p99 * 1e9).round()));
            measured.insert(format!("qps/{key}"),
                            Json::Num(qps.round()));
        }
        handle.stop();
    }
    print_table(
        "measured serving path: HTTP + micro-batcher + executor \
         (mlp_b32, rows/request = batch; QPS over 4 concurrent clients)",
        &["replicas", "batch", "p50", "p99", "rows/s"],
        &rows_out,
    );

    let summary: BTreeMap<String, Json> = [
        ("bench".to_string(), Json::Str("serve_bench".to_string())),
        ("ci".to_string(), Json::Bool(ci)),
        ("measured".to_string(), Json::Obj(measured)),
    ]
    .into_iter()
    .collect();
    write_json(&json_path, &Json::Obj(summary)).unwrap();
    println!("wrote {json_path}");

    // Idempotent merge of the deterministic serving block into the
    // committed trajectory file (same values allreduce_scaling writes).
    if let Some(path) = pr_json {
        let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            eprintln!("error: --pr-json {path}: {e} (run \
                       allreduce_scaling -- --pr-json first)");
            std::process::exit(2);
        });
        let mut top = match Json::parse(&text) {
            Ok(Json::Obj(m)) => m,
            _ => {
                eprintln!("error: {path} is not a JSON object");
                std::process::exit(2);
            }
        };
        // Never downgrade the file's schema: allreduce_scaling owns
        // the version stamp (currently 5); merging the serving block
        // into an already-stamped file must leave it alone, or the
        // staleness gate would flag a phantom diff.
        if top.get("schema").is_none() {
            top.insert("schema".into(), Json::Num(3.0));
        }
        top.insert("serving".into(), serving::bench_block());
        write_json(&path, &Json::Obj(top)).unwrap();
        println!("merged serving block into {path}");
    }
}
