//! Figure 2: model accuracy after a fixed number of epochs vs the number
//! of (asynchronous Downpour) workers. REAL runs — staleness is a
//! protocol property, not a parallel-hardware property, so a single-core
//! host reproduces it faithfully: with W workers, each gradient is ~W-1
//! master updates stale on average.
//!
//! Paper shape: accuracy "slowly decreases at high worker counts because
//! of workers training on outdated model information".
//!
//!     cargo bench --bench fig2_accuracy
//!     cargo bench --bench fig2_accuracy -- --workers 1,2,4,8,16 \
//!         --epochs 10 --total 16000

use mpi_learn::coordinator::{train, Algo, Data, ModelBuilder,
                             TrainConfig, Transport};
use mpi_learn::data::GeneratorConfig;
use mpi_learn::optim::OptimizerConfig;
use mpi_learn::util::bench::{print_table, write_csv};
use mpi_learn::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let worker_counts = args.usize_list("workers", &[1, 2, 4, 8, 16])
        .unwrap();
    let epochs = args.usize("epochs", 6).unwrap() as u32;
    let total = args.usize("total", 8000).unwrap();
    let seeds = args.usize_list("seeds", &[1, 2, 3]).unwrap();
    let separation = args.f64("separation", 0.07).unwrap() as f32;
    let noise = args.f64("noise", 2.5).unwrap() as f32;
    let lr = args.f64("lr", 0.08).unwrap() as f32;
    args.finish().unwrap();

    let session = match mpi_learn::runtime::Session::open_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP fig2_accuracy: {e}");
            return;
        }
    };

    // hard task so accuracy lives below the ceiling and the staleness
    // penalty is visible (DESIGN.md §Substitutions)
    let gen = GeneratorConfig { separation, noise,
                                ..Default::default() };

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for &w in &worker_counts {
        let mut accs = Vec::new();
        let mut stale_means = Vec::new();
        for &seed in &seeds {
            let data = Data::Synthetic {
                gen: GeneratorConfig { seed: seed as u64 * 7919,
                                       ..gen.clone() },
                samples_per_worker: total / w, // fixed TOTAL dataset
                val_samples: 2000,
            };
            let cfg = TrainConfig {
                builder: ModelBuilder::new("lstm", 100),
                algo: Algo {
                    batch_size: 100,
                    epochs,
                    validate_every: 0, // accuracy after training only
                    max_val_batches: 20,
                    // plain SGD isolates the staleness effect; the paper
                    // notes momentum *mitigates* it (we show that too)
                    optimizer: OptimizerConfig::Sgd { lr },
                    ..Algo::default()
                },
                n_workers: w,
                seed: seed as u64,
                transport: Transport::Inproc,
                hierarchy: None,
                callbacks: Vec::new(),
            };
            let r = train(&session, &cfg, &data).unwrap();
            let acc = r.history.final_val_acc().unwrap();
            accs.push(acc as f64);
            stale_means.push((w as f64 - 1.0).max(0.0)); // analytic note
        }
        let mean = mpi_learn::util::stats::mean(&accs);
        let std = mpi_learn::util::stats::std_dev(&accs);
        rows.push(vec![
            format!("{w}"),
            format!("{mean:.4}"),
            format!("{std:.4}"),
            format!("{:.0}", (total / w / 100 * 100 * w) as f64),
        ]);
        csv.push(vec![format!("{w}"), format!("{mean:.5}"),
                      format!("{std:.5}")]);
        println!("workers={w}: acc {mean:.4} ± {std:.4}");
    }
    print_table(
        &format!("Fig 2 — accuracy after {epochs} epochs vs workers \
                  (async Downpour, batch 100, plain SGD)"),
        &["workers", "val_acc mean", "val_acc std", "samples used"],
        &rows,
    );
    write_csv("runs/bench/fig2_accuracy.csv",
              &["workers", "acc_mean", "acc_std"], &csv).unwrap();

    // momentum mitigation (paper ref [9]) at the largest worker count
    let w = *worker_counts.last().unwrap();
    let data = Data::Synthetic {
        gen: GeneratorConfig { seed: 7919, ..gen.clone() },
        samples_per_worker: total / w,
        val_samples: 2000,
    };
    let mut cfg = TrainConfig {
        builder: ModelBuilder::new("lstm", 100),
        algo: Algo {
            batch_size: 100,
            epochs,
            max_val_batches: 20,
            // "a suitable choice of SGD momentum" (§IV, ref [9]):
            // staleness multiplies the effective step by ~1/(1-mu), so
            // the lr must shrink accordingly — same effective step as
            // the SGD baseline, but smoothed over ~4 gradients.
            optimizer: OptimizerConfig::Momentum {
                lr: 0.04, momentum: 0.5, nesterov: false },
            ..Algo::default()
        },
        n_workers: w,
        seed: 1,
        transport: Transport::Inproc,
        hierarchy: None,
        callbacks: Vec::new(),
    };
    cfg.algo.validate_every = 0;
    let r = train(&session, &cfg, &data).unwrap();
    println!("\nmitigation check ({w} workers, momentum 0.5 @ matched \
              effective step): acc {:.4}\n(paper §IV: staleness \
              degradation \"can be mitigated by a suitable choice of \
              SGD\nmomentum\" — on this synthetic task momentum roughly \
              matches tuned SGD; see\nEXPERIMENTS.md for the sweep)",
             r.history.final_val_acc().unwrap());
}
