//! Table I: speedup vs batch size with 20 workers, relative to batch 100.
//!
//!   paper:  batch 10 -> 0.1x, 100 -> 1.0x, 500 -> 3.0x, 1000 -> 4.1x
//!
//! Mechanism: "the frequency of weight updates is inversely proportional
//! to the batch size", so larger batches relieve the master bottleneck.
//! Every batch size's gradient cost is measured on its REAL compiled
//! artifact (lstm_b10/100/500/1000), then the 20-worker protocol is
//! simulated with those measured costs.
//!
//!     cargo bench --bench table1_batchsize

use mpi_learn::simulator::{measure_costs, simulate, CostModel, SimConfig};
use mpi_learn::util::bench::{print_table, write_csv};
use mpi_learn::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let workers = args.usize("workers", 20).unwrap();
    args.finish().unwrap();

    let session = match mpi_learn::runtime::Session::open_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP table1: {e}");
            return;
        }
    };

    let opt = mpi_learn::optim::OptimizerConfig::default_momentum();
    let batches = [10usize, 100, 500, 1000];
    let paper = [0.1, 1.0, 3.0, 4.1];

    // measure every artifact's real cost
    let mut measured = Vec::new();
    for &b in &batches {
        let exes = match session.executables_for("lstm", b) {
            Ok(e) => e,
            Err(_) => {
                eprintln!("SKIP table1: artifact lstm_b{b} missing \
                           (quick build?)");
                return;
            }
        };
        let reps = if b >= 500 { 6 } else { 15 };
        let cal = measure_costs(&exes, &opt, reps);
        println!("measured lstm_b{b}: grad {:.2}ms ({:.1}µs/sample)",
                 cal.t_grad * 1e3, cal.t_grad / b as f64 * 1e6);
        measured.push((b, cal));
    }

    let n_params = session.manifest.variant("lstm", 100).unwrap()
        .param_count;
    let total_samples = 950_000u64;

    // Two series (see fig4 for rationale):
    //   paper-scale: GPU workers (launch-bound, so t_grad barely grows
    //     with batch) + Python master (3.6 ms/update) — the regime the
    //     paper's 0.1/1.0/3.0/4.1 comes from;
    //   this-stack: every batch size's gradient cost measured on its
    //     real compiled artifact + measured Rust master cost.
    let run = |mk_cost: &dyn Fn(usize, f64, f64) -> CostModel|
        -> Vec<(usize, f64, f64)> {
        measured
            .iter()
            .map(|(b, cal)| {
                let cost = mk_cost(*b, cal.t_grad, cal.t_update);
                let cfg = SimConfig {
                    n_workers: workers,
                    total_samples,
                    batch: *b,
                    epochs: 10,
                    validate_every: 0,
                    sync: false,
                };
                let r = simulate(&cost, &cfg, 2017 ^ *b as u64);
                (*b, r.total_time_s, r.master_utilization)
            })
            .collect()
    };

    let paper_scale = run(&|_b, _tg, _tu| CostModel::paper_gpu(n_params));
    let this_stack = run(&|b, t_grad, t_update| {
        let mut cost = CostModel::cluster(n_params);
        // exact per-batch cost: fixed = 0, per-sample = measured/batch
        cost.t_grad_fixed = 0.0;
        cost.t_grad_per_sample = t_grad / b as f64;
        cost.t_update = t_update;
        cost
    });

    let t100_p = paper_scale.iter().find(|(b, _, _)| *b == 100)
        .unwrap().1;
    let t100_s = this_stack.iter().find(|(b, _, _)| *b == 100)
        .unwrap().1;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (i, ((b, tp, util_p), (_, ts, _))) in
        paper_scale.iter().zip(&this_stack).enumerate() {
        let sp = t100_p / tp;
        let ss = t100_s / ts;
        rows.push(vec![
            format!("{b}"),
            format!("{}", paper[i]),
            format!("{sp:.1}"),
            format!("{ss:.1}"),
            format!("{:.0}%", util_p * 100.0),
        ]);
        csv.push(vec![format!("{b}"), format!("{}", paper[i]),
                      format!("{sp:.4}"), format!("{ss:.4}")]);
    }
    print_table(
        &format!("Table I — speedup vs batch size ({workers} workers, \
                  relative to batch 100)"),
        &["batch", "paper", "paper-scale sim", "this-stack sim",
          "master util (paper-scale)"],
        &rows,
    );
    write_csv("runs/bench/table1_batchsize.csv",
              &["batch", "paper", "paper_scale", "this_stack"], &csv)
        .unwrap();
    println!("\nshape check: monotone in batch size with small batches \
              master-bound, matching\nthe paper's 0.1/1.0/3.0/4.1. The \
              this-stack column is flatter because CPU grad\ncost grows \
              ~linearly with batch (no GPU launch-bound regime) and the \
              Rust\nmaster is far from saturation at 20 workers.");
}
