//! Runtime microbench: PJRT gradient/eval step latency per batch size,
//! plus the master-side optimizer update cost.
//!
//! These numbers calibrate the protocol simulator (Figs 3/4, Table I) and
//! feed EXPERIMENTS.md §Calibration. Run with:
//!
//!     cargo bench --bench runtime_microbench
//!     cargo bench --bench runtime_microbench -- --ci \
//!         --json runs/bench/runtime_microbench.json
//!
//! `--json` also records the compute-kernel GFLOP/s sweep (kernel x
//! shape x threads), which `tools/bench_gate.py compute` checks for
//! thread-pool speedup on the large shape.

use std::collections::BTreeMap;

use mpi_learn::optim::OptimizerConfig;
use mpi_learn::runtime::{kernel_gflops, Session};
use mpi_learn::tensor::ParamSet;
use mpi_learn::util::bench::{fmt_secs, measure, print_table, write_csv,
                             write_json};
use mpi_learn::util::cli::Args;
use mpi_learn::util::json::Json;
use mpi_learn::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let ci = args.bool("ci");
    let json_path =
        args.str("json", "runs/bench/runtime_microbench.json");
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }

    let session = match Session::open_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP runtime_microbench: {e} (run `make \
                       artifacts`)");
            return;
        }
    };

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for key in ["lstm_b10", "lstm_b100", "lstm_b500", "lstm_b1000",
                "mlp_b100", "transformer_b16"] {
        let exes = match session.executables(key) {
            Ok(e) => e,
            Err(_) => continue, // quick artifact sets lack some variants
        };
        let meta = exes.meta.clone();
        let mut rng = Rng::new(1);
        let params = exes.init_params(&mut rng);
        let x: Vec<f32> = (0..meta.x_len())
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let y: Vec<i32> = (0..meta.batch)
            .map(|_| rng.usize_below(meta.classes) as i32)
            .collect();

        let iters = if meta.batch >= 500 { 8 } else { 20 };
        let g = measure("grad", 2, iters,
                        || { exes.grad_step(&params, &x, &y).unwrap(); });
        let e = measure("eval", 2, iters,
                        || { exes.eval_step(&params, &x, &y).unwrap(); });
        // price input marshalling separately (perf pass, EXPERIMENTS
        // §Perf): literal creation + reshape for params + x + y
        let m = measure("marshal", 2, iters, || {
            exes.marshal_inputs(&params, &x, &y).unwrap();
        });
        let per_sample_us = g.mean_s / meta.batch as f64 * 1e6;
        rows.push(vec![
            key.to_string(),
            format!("{}", meta.param_count),
            fmt_secs(g.mean_s),
            fmt_secs(g.p95_s),
            fmt_secs(e.mean_s),
            fmt_secs(m.mean_s),
            format!("{:.1}%", 100.0 * m.mean_s / g.mean_s),
            format!("{per_sample_us:.1}"),
        ]);
        csv.push(vec![
            key.to_string(),
            format!("{}", meta.batch),
            format!("{}", meta.param_count),
            format!("{:.6e}", g.mean_s),
            format!("{:.6e}", e.mean_s),
        ]);
    }
    print_table(
        "PJRT step latency (grad = fwd+bwd+literal marshalling)",
        &["artifact", "params", "grad mean", "grad p95", "eval mean",
          "marshal", "marshal %", "grad µs/sample"],
        &rows,
    );
    write_csv("runs/bench/runtime_microbench.csv",
              &["artifact", "batch", "params", "grad_s", "eval_s"],
              &csv).unwrap();

    // ---- scratch-arena delta (native backend) ----
    // The native engine pools forward/backward scratch buffers in a
    // per-worker arena; flipping reuse off prices the steady-state
    // allocation traffic the arena removes. (Identical results either
    // way — see native::tests::scratch_reuse_does_not_change_results.)
    let mut rows = Vec::new();
    for key in ["lstm_b100", "mlp_b100"] {
        let exes = match session.executables(key) {
            Ok(e) => e,
            Err(_) => continue,
        };
        if exes.backend_name() != "native" {
            continue; // PJRT manages its own buffers
        }
        let meta = exes.meta.clone();
        let mut rng = Rng::new(1);
        let params = exes.init_params(&mut rng);
        let x: Vec<f32> = (0..meta.x_len())
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let y: Vec<i32> = (0..meta.batch)
            .map(|_| rng.usize_below(meta.classes) as i32)
            .collect();
        exes.set_scratch_reuse(true);
        let pooled = measure("grad/arena", 2, 20,
                             || { exes.grad_step(&params, &x, &y)
                                      .unwrap(); });
        exes.set_scratch_reuse(false);
        let fresh = measure("grad/alloc", 2, 20,
                            || { exes.grad_step(&params, &x, &y)
                                     .unwrap(); });
        exes.set_scratch_reuse(true);
        rows.push(vec![
            key.to_string(),
            fmt_secs(pooled.mean_s),
            fmt_secs(fresh.mean_s),
            format!("{:.1}%",
                    100.0 * (fresh.mean_s - pooled.mean_s)
                        / fresh.mean_s),
        ]);
    }
    if !rows.is_empty() {
        print_table(
            "native grad step: pooled scratch arena vs per-step \
             allocation",
            &["artifact", "arena", "alloc", "arena saves"],
            &rows,
        );
    }

    // ---- optimizer update cost (the master's serial work) ----
    let mut rows = Vec::new();
    for (name, opt_cfg) in [
        ("sgd", OptimizerConfig::Sgd { lr: 0.05 }),
        ("momentum", OptimizerConfig::default_momentum()),
        ("adam", OptimizerConfig::Adam { lr: 1e-3, beta1: 0.9,
                                         beta2: 0.999, eps: 1e-8 }),
    ] {
        for n in [3_023usize, 32_963, 798_467] {
            let mut opt = opt_cfg.build(n);
            let mut w = ParamSet::zeros(&[("w".into(), vec![n])]);
            let g = vec![1e-3f32; n];
            let m = measure("opt", 10, 200,
                            || opt.update(w.flat_mut(), &g));
            rows.push(vec![
                name.to_string(),
                format!("{n}"),
                fmt_secs(m.mean_s),
                format!("{:.1}", n as f64 / m.mean_s / 1e6),
            ]);
        }
    }
    print_table(
        "master optimizer update cost (per incoming gradient)",
        &["optimizer", "params", "mean", "Mparams/s"],
        &rows,
    );

    // ---- compute kernels: GFLOP/s per kernel x shape x threads ----
    // The lane-chunked pooled GEMMs (DESIGN.md §Compute kernels) are
    // bitwise-identical at any thread count, so the only question the
    // bench answers is throughput. "small" sits below the inline
    // cutoff (the pool is bypassed, so all thread counts tie); "large"
    // is the calibration probe's shape, where threads=4 must beat
    // threads=1 — the `bench_gate.py compute` check.
    let shapes: &[(&str, usize, usize, usize)] = &[
        ("small", 16, 64, 32),
        ("medium", 64, 256, 64),
        ("large", 100, 480, 64),
    ];
    let threads = [1usize, 2, 4];
    let reps = if ci { 3 } else { 8 };
    let mut rows = Vec::new();
    let mut gflops: BTreeMap<String, Json> = BTreeMap::new();
    for kernel in ["nn", "tn", "nt"] {
        for &(tag, m, k, n) in shapes {
            let mut row = vec![kernel.to_string(),
                               format!("{tag} ({m}x{k}x{n})")];
            let mut by_t = Vec::new();
            for &t in &threads {
                let g = kernel_gflops(kernel, t, m, k, n, reps);
                gflops.insert(format!("{kernel}/{tag}/t{t}"),
                              Json::Num(g));
                row.push(format!("{g:.2}"));
                by_t.push(g);
            }
            row.push(format!("{:.2}x", by_t[2] / by_t[0]));
            rows.push(row);
        }
    }
    print_table(
        "compute kernel throughput (GFLOP/s, pooled lane-chunked GEMMs)",
        &["kernel", "shape (m x k x n)", "t=1", "t=2", "t=4",
          "t4/t1"],
        &rows,
    );

    let summary: BTreeMap<String, Json> = [
        ("bench".to_string(),
         Json::Str("runtime_microbench".to_string())),
        ("ci".to_string(), Json::Bool(ci)),
        ("compute_gflops".to_string(), Json::Obj(gflops)),
    ]
    .into_iter()
    .collect();
    write_json(&json_path, &Json::Obj(summary)).unwrap();
    println!("wrote {json_path}");

    println!("\nThese means parameterize CostModel::{{t_grad_*, t_update, \
              gemm_*}} for the Fig 3/4/Table I sweeps.");
}
