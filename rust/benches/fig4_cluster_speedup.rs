//! Figure 4: training speedup on the ALCF Cooley cluster (1 GPU/node,
//! FDR Infiniband) up to 60 workers — paper observes ~30x at 60 with
//! batch 100, the deviation "driven by the time needed for the master
//! process to update the weights ... and transmit them back".
//!
//! Regenerated with the protocol simulator (cluster preset, live-
//! calibrated compute costs; see fig3 for why simulation — 1-core host).
//! Also sweeps validation frequency to reproduce the §V claim that more
//! validation breaks linearity earlier.
//!
//!     cargo bench --bench fig4_cluster_speedup

use mpi_learn::simulator::{measure_costs, simulate, CostModel, SimConfig};
use mpi_learn::util::bench::{print_table, write_csv};
use mpi_learn::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let worker_counts = args
        .usize_list("workers", &[1, 2, 4, 8, 15, 22, 30, 40, 50, 60])
        .unwrap();
    args.finish().unwrap();

    let session = match mpi_learn::runtime::Session::open_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP fig4: {e}");
            return;
        }
    };
    let exes = session.executables("lstm_b100").unwrap();
    let opt = mpi_learn::optim::OptimizerConfig::default_momentum();
    let cal = measure_costs(&exes, &opt, 15);
    let mut cost = CostModel::cluster(exes.meta.param_count);
    if let Ok(e10) = session.executables("lstm_b10") {
        let cal10 = measure_costs(&e10, &opt, 15);
        cal.apply_with_small_batch(&cal10, &mut cost);
    } else {
        cal.apply(&mut cost);
    }

    // paper-sized dataset: 100 files x 9500 samples, 10 epochs, batch 100
    let base = SimConfig {
        n_workers: 1,
        total_samples: 950_000,
        batch: 100,
        epochs: 10,
        validate_every: 0,
        sync: false,
    };

    // The paper's testbed had GPU workers and a Python/Keras master,
    // whose per-gradient service cost (~3.6 ms, derived from the paper's
    // own 30x@60 saturation) dominates the curve shape. Our Rust master
    // measures ~3 orders of magnitude cheaper, so we report BOTH:
    //   paper-scale — CostModel::paper_gpu, reproduces Fig 4's shape;
    //   this-stack  — live-calibrated costs, shows where OUR system
    //                 would saturate.
    let paper_cost = CostModel::paper_gpu(exes.meta.param_count);

    // validation-frequency series on the paper-scale model (§V claim).
    // t_val: a 20-batch validation round at paper per-batch eval cost
    // (~half a training step).
    let t_val_paper = 20.0 * 0.5 * paper_cost.grad_time_nominal(100);
    let series: [(&str, &CostModel, u64, f64); 4] = [
        ("paper-scale", &paper_cost, 0, 0.0),
        ("paper+light-val", &paper_cost, 500, t_val_paper),
        ("paper+heavy-val", &paper_cost, 100, t_val_paper),
        ("this-stack", &cost, 0, 0.0),
    ];

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for &w in &worker_counts {
        let mut row = vec![format!("{w}")];
        let mut csv_row = vec![format!("{w}")];
        for (_, model, every, t_val) in &series {
            let mut c = (*model).clone();
            c.t_val = *t_val;
            let t1 = simulate(&c, &SimConfig { validate_every: *every,
                                               ..base.clone() }, 2017)
                .total_time_s;
            let r = simulate(&c, &SimConfig { n_workers: w,
                                              validate_every: *every,
                                              ..base.clone() },
                             2017 ^ w as u64);
            let s = t1 / r.total_time_s;
            row.push(format!("{s:.1}"));
            csv_row.push(format!("{s:.4}"));
        }
        rows.push(row);
        csv.push(csv_row);
        println!("workers={w}: done");
    }
    print_table(
        "Fig 4 — cluster speedup vs workers (batch 100)",
        &["workers", "paper-scale", "paper+light-val", "paper+heavy-val",
          "this-stack (rust master)"],
        &rows,
    );
    write_csv("runs/bench/fig4_cluster_speedup.csv",
              &["workers", "paper_scale", "paper_light_val",
                "paper_heavy_val", "this_stack"],
              &csv).unwrap();

    let last = rows.last().unwrap();
    println!("\npaper: ~30x at 60 workers — paper-scale series here: \
              {}x at {} workers.\nMore validation -> earlier break from \
              linearity (§V). The 'this-stack' series\nshows the same \
              protocol with the measured Rust master (~{:.0}ns/update \
              +\n~µs messaging): the master bottleneck moves out by \
              ~3 orders of magnitude.",
             last[1], last[0], cost.t_update * 1e9);
}
