//! Ring all-reduce scaling: measured collective latency on the inproc
//! transport (per wire codec, flat ring vs hierarchical ring+tree),
//! plus simulated Fig-3/4-style speedup curves comparing the
//! parameter-server protocol against the masterless ring — raw,
//! compressed, and hierarchical. The PS master saturates; the flat ring
//! pays a `2(n-1)` latency term; the hierarchical schedule collapses it
//! to `2(m-1) + O(log G)`.
//!
//!     cargo bench --bench allreduce_scaling
//!     cargo bench --bench allreduce_scaling -- --ci --json out.json
//!     cargo bench --bench allreduce_scaling -- --worlds 8,16,32 \
//!         --json nightly.json               # nightly scaling sweep
//!     cargo bench --bench allreduce_scaling -- --ci \
//!         --pr-json ../BENCH_pr.json        # committed trajectory

use std::collections::BTreeMap;

use mpi_learn::coordinator::planner;
use mpi_learn::mpi;
use mpi_learn::mpi::collective::{Collective, GroupLayout, ReduceOp};
use mpi_learn::mpi::Codec;
use mpi_learn::simulator::{simulate_allreduce, simulate_async,
                           simulate_hier_allreduce, CostModel,
                           SimConfig};
use mpi_learn::util::bench::{fmt_secs, print_table, write_csv,
                             write_json};
use mpi_learn::util::cli::Args;
use mpi_learn::util::json::Json;

/// Group count used for hierarchical curves at world size `n`: groups
/// of ~4 ranks ("one node"), at least 2 groups.
fn groups_for(n: usize) -> usize {
    (n / 4).max(2)
}

/// Wall time per all-reduce for `n` ranks over `floats` elements; with
/// a layout, the hierarchical ring → tree → ring schedule runs instead
/// of the flat ring.
fn measure_ring(n: usize, floats: usize, reps: usize, codec: Codec,
                layout: Option<&GroupLayout>) -> f64 {
    let world = mpi::inproc_world(n);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for comm in world {
            let layout = layout.cloned();
            s.spawn(move || {
                let mut col = Collective::new(&comm);
                col.set_codec(codec);
                col.set_groups(layout);
                let mut buf = vec![1.0f32; floats];
                // one warmup + timed reps (all ranks in lockstep, so
                // per-rank timing equals wall timing)
                for _ in 0..reps + 1 {
                    col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                }
            });
        }
    });
    // subtract nothing for the warmup: it amortizes thread spawn
    t0.elapsed().as_secs_f64() / (reps + 1) as f64
}

/// Total wire bytes (all ranks) of ONE flat-ring all-reduce — a
/// deterministic quantity (chunk sizes and top-k keep-counts depend
/// only on the shape), which is what lets BENCH_pr.json be committed.
fn measure_bytes_per_round(n: usize, floats: usize, codec: Codec)
    -> u64 {
    let world = mpi::inproc_world(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = world
            .into_iter()
            .map(|comm| {
                s.spawn(move || {
                    let mut col = Collective::new(&comm);
                    col.set_codec(codec);
                    let mut buf = vec![1.0f32; floats];
                    let before = comm.bytes_sent();
                    col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                    comm.bytes_sent() - before
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    })
}

/// The committed, stable-schema perf trajectory (repo-root
/// BENCH_pr.json). Every value is a deterministic integer — measured
/// wire bytes per round per codec, and the closed-form cost-model
/// collective times (ns) for flat vs hierarchical per world size — so
/// CI can regenerate the file and `git diff` it against the committed
/// copy.
fn write_bench_pr(path: &str) {
    let n_params = 3_023usize; // the paper LSTM's parameter count
    let ranks = 4usize;
    let codecs = [Codec::Fp32, Codec::Fp16, Codec::TopK { k: 0.1 }];
    let mut bytes: BTreeMap<String, Json> = BTreeMap::new();
    for codec in codecs {
        bytes.insert(
            codec.name(),
            Json::Num(measure_bytes_per_round(ranks, n_params, codec)
                as f64),
        );
    }
    let cost = CostModel::cluster(n_params);
    let mut flat: BTreeMap<String, Json> = BTreeMap::new();
    let mut hier: BTreeMap<String, Json> = BTreeMap::new();
    let mut hier_groups: BTreeMap<String, Json> = BTreeMap::new();
    for n in [2usize, 4, 8, 16, 32, 64] {
        let g = groups_for(n);
        let key = format!("n{n}");
        flat.insert(key.clone(), Json::Num(
            (cost.ring_allreduce_time(n) * 1e9).round()));
        hier.insert(key.clone(), Json::Num(
            (cost.hierarchical_allreduce_time(n, g) * 1e9).round()));
        hier_groups.insert(key, Json::Num(g as f64));
    }
    let mut collective: BTreeMap<String, Json> = BTreeMap::new();
    collective.insert("flat".into(), Json::Obj(flat));
    collective.insert("hier".into(), Json::Obj(hier));
    collective.insert("hier_groups".into(), Json::Obj(hier_groups));
    // overlap column: round wall-clock (gradient start → reduced
    // gradients, ns) for the bucketed compute-overlapped schedule vs
    // the serial one (full backprop, then one standalone reduce).
    // `buckets` mirrors the paper LSTM's layer DAG: cell + head + the
    // piggybacked loss/stop tail. The CI bench-smoke gate asserts
    // bucketed < serial for every n >= 8.
    let batch = 100usize;
    let buckets = 3usize;
    let mut bucketed: BTreeMap<String, Json> = BTreeMap::new();
    let mut serial: BTreeMap<String, Json> = BTreeMap::new();
    for n in [2usize, 4, 8, 16, 32, 64] {
        let key = format!("n{n}");
        bucketed.insert(key.clone(), Json::Num(
            (cost.bucketed_allreduce_time(n, batch, buckets) * 1e9)
                .round()));
        serial.insert(key, Json::Num(
            ((cost.grad_time_nominal(batch)
                + cost.ring_allreduce_time(n)) * 1e9).round()));
    }
    let mut overlap: BTreeMap<String, Json> = BTreeMap::new();
    overlap.insert("batch".into(), Json::Num(batch as f64));
    overlap.insert("buckets".into(), Json::Num(buckets as f64));
    overlap.insert("bucketed_ns".into(), Json::Obj(bucketed));
    overlap.insert("serial_ns".into(), Json::Obj(serial));
    // schema 5: the intra-rank compute term — closed-form GEMM
    // throughput (MFLOP/s, integer) per thread count from the cluster
    // preset's Amdahl model, plus the modeled GEMM wall time (ns) for
    // the microbench shapes. "small" sits below the engine's inline
    // cutoff, so its time is thread-invariant by construction — the
    // model mirrors the real kernels' serial fallback. Measured
    // per-kernel GFLOP/s live in the uncommitted runtime_microbench
    // JSON; the CI compute gate asserts t4 > t1 MFLOP/s here.
    let mut mflops: BTreeMap<String, Json> = BTreeMap::new();
    for t in [1usize, 2, 4, 8] {
        mflops.insert(format!("t{t}"), Json::Num(
            (cost.gemm_gflops(t) * 1e3).round()));
    }
    let gemm_shapes: &[(&str, usize, usize, usize)] = &[
        ("small", 16, 64, 32),
        ("medium", 64, 256, 64),
        ("large", 100, 480, 64),
    ];
    let mut gemm_ns: BTreeMap<String, Json> = BTreeMap::new();
    for &(tag, m, k, n) in gemm_shapes {
        let mut by_t: BTreeMap<String, Json> = BTreeMap::new();
        for t in [1usize, 2, 4, 8] {
            by_t.insert(format!("t{t}"), Json::Num(
                (cost.gemm_time(m, k, n, t) * 1e9).round()));
        }
        gemm_ns.insert(tag.into(), Json::Obj(by_t));
    }
    let mut compute: BTreeMap<String, Json> = BTreeMap::new();
    compute.insert("base_mflops".into(), Json::Num(
        (cost.gemm_base_gflops * 1e3).round()));
    compute.insert("parallel_frac_ppm".into(), Json::Num(
        (cost.gemm_parallel_frac * 1e6).round()));
    compute.insert("mflops".into(), Json::Obj(mflops));
    compute.insert("gemm_time_ns".into(), Json::Obj(gemm_ns));
    // schema 4: the planner's decision surface on the same cluster
    // preset — per world size, every (topology x codec) candidate's
    // predicted round time (ns) and the chosen key, plus the link
    // costs the sweep ran on. All closed-form, so the committed copy
    // regenerates bit-identically; measured-vs-predicted comparisons
    // live in the uncommitted run artifacts instead. The CI planner
    // gate asserts chosen == argmin of its own candidate listing.
    let sweep_codecs = [Codec::Fp32, Codec::Fp16];
    let mut predicted: BTreeMap<String, Json> = BTreeMap::new();
    let mut chosen: BTreeMap<String, Json> = BTreeMap::new();
    for n in [2usize, 4, 8, 16, 32, 64] {
        let choice = planner::sweep(&cost, n, batch, &sweep_codecs,
                                    false);
        let key = format!("n{n}");
        let mut cands: BTreeMap<String, Json> = BTreeMap::new();
        for c in &choice.candidates {
            cands.insert(c.key(),
                         Json::Num((c.predicted_s * 1e9).round()));
        }
        predicted.insert(key.clone(), Json::Obj(cands));
        chosen.insert(key, Json::Str(choice.chosen.key()));
    }
    let mut links: BTreeMap<String, Json> = BTreeMap::new();
    links.insert("inter_latency_ns".into(),
                 Json::Num((cost.latency * 1e9).round()));
    links.insert("inter_bw_bps".into(),
                 Json::Num(cost.bandwidth_bytes_per_s));
    links.insert("intra_latency_ns".into(),
                 Json::Num((cost.intra_latency * 1e9).round()));
    links.insert("intra_bw_bps".into(),
                 Json::Num(cost.intra_bandwidth_bytes_per_s));
    let mut planner_block: BTreeMap<String, Json> = BTreeMap::new();
    planner_block.insert("batch".into(), Json::Num(batch as f64));
    planner_block.insert("link_costs".into(), Json::Obj(links));
    planner_block.insert("predicted_ns".into(), Json::Obj(predicted));
    planner_block.insert("chosen".into(), Json::Obj(chosen));
    let mut top: BTreeMap<String, Json> = BTreeMap::new();
    top.insert("bench".into(), Json::Str("bench_pr".into()));
    top.insert("bytes_per_round".into(), Json::Obj(bytes));
    top.insert("collective_ns".into(), Json::Obj(collective));
    top.insert("compute".into(), Json::Obj(compute));
    top.insert("overlap".into(), Json::Obj(overlap));
    top.insert("params".into(), Json::Num(n_params as f64));
    top.insert("planner".into(), Json::Obj(planner_block));
    top.insert("ranks".into(), Json::Num(ranks as f64));
    top.insert("schema".into(), Json::Num(5.0));
    // schema 3: the serving-path block (closed-form like collective_ns;
    // the formula lives in mpi_learn::serving so benches/serve_bench.rs
    // emits the identical numbers).
    top.insert("serving".into(), mpi_learn::serving::bench_block());
    write_json(path, &Json::Obj(top)).unwrap();
    println!("wrote {path}");
}

fn main() {
    let args = Args::from_env();
    let ci = args.bool("ci");
    let json_path = args.str("json", "runs/bench/allreduce_scaling.json");
    let pr_json = args.str_opt("pr-json");
    let default_worlds: Vec<usize> =
        if ci { vec![2, 4] } else { vec![2, 4, 8] };
    let worlds = match args.usize_list("worlds", &default_worlds) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }

    // ---- measured: inproc ring all-reduce, per codec ----
    let sizes: &[(usize, &str)] = if ci {
        &[(3_023, "lstm"), (32_963, "mlp")]
    } else {
        &[(3_023, "lstm"), (32_963, "mlp"), (262_144, "1MB")]
    };
    let codecs = [
        ("fp32", Codec::Fp32),
        ("fp16", Codec::Fp16),
        ("topk10", Codec::TopK { k: 0.1 }),
    ];
    let reps_for = |floats: usize| match (ci, floats > 100_000) {
        (true, _) => 10,
        (false, true) => 30,
        (false, false) => 100,
    };
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut measured: BTreeMap<String, f64> = BTreeMap::new();
    for &(floats, tag) in sizes {
        for (cname, codec) in codecs {
            let mut row = vec![format!("{tag} ({floats} f32)"),
                               cname.to_string()];
            for &n in &worlds {
                let t = measure_ring(n, floats, reps_for(floats), codec,
                                     None);
                // per-rank payload volume of the chunked ring
                let bytes = 2.0 * (n as f64 - 1.0) / n as f64
                    * (floats * 4) as f64 * codec.wire_ratio();
                row.push(format!("{} ({:.2} GB/s)", fmt_secs(t),
                                 bytes / t / 1e9));
                measured.insert(format!("{tag}/{cname}/n{n}"), t);
                csv.push(vec![
                    tag.to_string(),
                    cname.to_string(),
                    format!("{floats}"),
                    format!("{n}"),
                    format!("{t:.3e}"),
                ]);
            }
            rows.push(row);
        }
    }
    let mut header = vec!["payload", "codec"];
    let world_labels: Vec<String> =
        worlds.iter().map(|n| format!("n={n}")).collect();
    header.extend(world_labels.iter().map(|s| s.as_str()));
    print_table(
        "measured inproc ring all-reduce (time + algorithm bandwidth)",
        &header,
        &rows,
    );
    write_csv("runs/bench/allreduce_inproc.csv",
              &["payload", "codec", "floats", "ranks", "time_s"],
              &csv).unwrap();

    // ---- measured: flat ring vs hierarchical (fp32) ----
    // Inproc threads have no real inter-node latency gap, so this is a
    // correctness/overhead check, not the wall-clock argument — that is
    // what the simulated curves below model.
    let mut rows = Vec::new();
    for &(floats, tag) in sizes {
        for &n in &worlds {
            let g = groups_for(n);
            if n < 4 || n % g != 0 {
                continue;
            }
            let layout = GroupLayout::contiguous(n, g).unwrap();
            let reps = reps_for(floats);
            // the codec loop above already measured the flat fp32 ring
            // for every (payload, world) cell — reuse it
            let t_flat = measured[&format!("{tag}/fp32/n{n}")];
            let t_hier = measure_ring(n, floats, reps, Codec::Fp32,
                                      Some(&layout));
            measured.insert(format!("{tag}/hier-g{g}/n{n}"), t_hier);
            rows.push(vec![
                format!("{tag} ({floats} f32)"),
                format!("{n}"),
                format!("{g}"),
                fmt_secs(t_flat),
                fmt_secs(t_hier),
                format!("{:.2}", t_flat / t_hier),
            ]);
        }
    }
    if !rows.is_empty() {
        print_table(
            "measured flat ring vs hierarchical (fp32, inproc)",
            &["payload", "ranks", "groups", "flat", "hier",
              "flat/hier"],
            &rows,
        );
    }

    // ---- simulated: PS vs ring vs hierarchical at paper scale ----
    // paper_gpu: the testbed whose master saturates at ~30x (Fig 4).
    let cost = CostModel::paper_gpu(3_023);
    let cost_fp16 = cost.clone().with_compression(Codec::Fp16);
    let base = SimConfig {
        n_workers: 1,
        total_samples: if ci { 95_000 } else { 950_000 },
        batch: 100,
        epochs: if ci { 1 } else { 10 },
        validate_every: 0,
        sync: false,
    };
    let t1 = simulate_async(&cost, &base, 2017).total_time_s;
    let t1_ring = simulate_allreduce(&cost, &base, 2017).total_time_s;
    let t1_ring16 =
        simulate_allreduce(&cost_fp16, &base, 2017).total_time_s;
    let t1_hier =
        simulate_hier_allreduce(&cost, &base, 2, 2017).total_time_s;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut sim_times: BTreeMap<String, f64> = BTreeMap::new();
    for w in [1usize, 2, 4, 8, 16, 30, 45, 60, 120] {
        let cfg = SimConfig { n_workers: w, ..base.clone() };
        let seed = 2017 ^ w as u64;
        let g = groups_for(w);
        let t_ps = simulate_async(&cost, &cfg, seed).total_time_s;
        let t_ring = simulate_allreduce(&cost, &cfg, seed).total_time_s;
        let t_ring16 =
            simulate_allreduce(&cost_fp16, &cfg, seed).total_time_s;
        let t_hier =
            simulate_hier_allreduce(&cost, &cfg, g, seed).total_time_s;
        sim_times.insert(format!("ring/n{w}"), t_ring);
        sim_times.insert(format!("hier/n{w}"), t_hier);
        let ps = t1 / t_ps;
        let ring = t1_ring / t_ring;
        let ring16 = t1_ring16 / t_ring16;
        let hier = t1_hier / t_hier;
        rows.push(vec![
            format!("{w}"),
            format!("{ps:.2}"),
            format!("{ring:.2}"),
            format!("{ring16:.2}"),
            format!("{hier:.2} (g={g})"),
            format!("{:.2}", hier / ring),
        ]);
        csv.push(vec![format!("{w}"), format!("{ps:.4}"),
                      format!("{ring:.4}"), format!("{ring16:.4}"),
                      format!("{hier:.4}")]);
    }
    print_table(
        "simulated speedup: parameter server vs ring vs hierarchical \
         all-reduce (paper-GPU preset, batch 100)",
        &["workers", "PS speedup", "ring speedup", "ring+fp16",
          "hier ring+tree", "hier/ring"],
        &rows,
    );
    write_csv("runs/bench/allreduce_vs_ps.csv",
              &["workers", "ps_speedup", "ring_speedup",
                "ring_fp16_speedup", "hier_speedup"],
              &csv).unwrap();
    println!("\nThe PS curve saturates at ~1/t_update gradients/s \
              (Figs 3/4); the flat ring keeps scaling until its \
              2(n-1)*lat term catches up; the hierarchical schedule \
              pays 2(m-1) cheap intra-group steps plus O(log G) \
              inter-group tree levels instead, so it keeps climbing \
              where the flat ring flattens.");

    // ---- simulated: bucketed overlap vs serial round wall-clock ----
    // cluster preset — the regime the bucketed schedule targets
    // (compute comparable to comm). 3 buckets = the paper LSTM's DAG
    // (cell + head + piggyback tail).
    let cost_cl = CostModel::cluster(3_023);
    let mut rows = Vec::new();
    for n in [2usize, 4, 8, 16, 32, 64] {
        let serial = cost_cl.grad_time_nominal(100)
            + cost_cl.ring_allreduce_time(n);
        let bucketed = cost_cl.bucketed_allreduce_time(n, 100, 3);
        sim_times.insert(format!("serial_round/n{n}"), serial);
        sim_times.insert(format!("bucketed_round/n{n}"), bucketed);
        rows.push(vec![
            format!("{n}"),
            fmt_secs(serial),
            fmt_secs(bucketed),
            format!("{:.3}", serial / bucketed),
        ]);
    }
    print_table(
        "simulated round wall-clock: serial (backprop then reduce) vs \
         bucketed overlapped all-reduce (cluster preset, batch 100, \
         3 buckets)",
        &["ranks", "serial", "bucketed", "overlap gain"],
        &rows,
    );

    // ---- the planner's sweep on the same cluster preset ----
    // The decision surface `--auto` navigates: per world size, the
    // chosen (topology, codec) and its predicted round time, next to
    // the measured flat-ring collectives above.
    let mut planner_chosen: BTreeMap<String, Json> = BTreeMap::new();
    let mut rows = Vec::new();
    for n in [2usize, 4, 8, 16, 32, 64] {
        let choice = planner::sweep(&cost_cl, n, 100,
                                    &[Codec::Fp32, Codec::Fp16], false);
        sim_times.insert(format!("planner_pred_round/n{n}"),
                         choice.chosen.predicted_s);
        planner_chosen.insert(format!("n{n}"),
                              Json::Str(choice.chosen.key()));
        rows.push(vec![
            format!("{n}"),
            choice.chosen.key(),
            fmt_secs(choice.chosen.predicted_s),
            format!("{}", choice.candidates.len()),
        ]);
    }
    print_table(
        "planner sweep: chosen plan per world size (cluster preset, \
         batch 100)",
        &["ranks", "chosen", "predicted round", "candidates"],
        &rows,
    );

    let summary: BTreeMap<String, Json> = [
        ("bench".to_string(),
         Json::Str("allreduce_scaling".to_string())),
        ("ci".to_string(), Json::Bool(ci)),
        ("measured_s".to_string(),
         Json::Obj(measured
             .iter()
             .map(|(k, v)| (k.clone(), Json::Num(*v)))
             .collect())),
        ("simulated_s".to_string(),
         Json::Obj(sim_times
             .iter()
             .map(|(k, v)| (k.clone(), Json::Num(*v)))
             .collect())),
        ("planner_chosen".to_string(), Json::Obj(planner_chosen)),
    ]
    .into_iter()
    .collect();
    write_json(&json_path, &Json::Obj(summary)).unwrap();
    println!("wrote {json_path}");

    if let Some(path) = pr_json {
        write_bench_pr(&path);
    }
}
