//! Ring all-reduce scaling: measured collective latency on the inproc
//! transport, plus simulated Fig-3/4-style speedup curves comparing the
//! parameter-server protocol against the masterless ring — the
//! motivation for `Mode::AllReduce` (the PS master saturates; the ring
//! does not).
//!
//!     cargo bench --bench allreduce_scaling

use mpi_learn::mpi;
use mpi_learn::mpi::collective::{Collective, ReduceOp};
use mpi_learn::simulator::{simulate_allreduce, simulate_async,
                           CostModel, SimConfig};
use mpi_learn::util::bench::{fmt_secs, print_table, write_csv};

/// Wall time per all-reduce for `n` ranks over `floats` elements.
fn measure_ring(n: usize, floats: usize, reps: usize) -> f64 {
    let world = mpi::inproc_world(n);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for comm in world {
            s.spawn(move || {
                let mut col = Collective::new(&comm);
                let mut buf = vec![1.0f32; floats];
                // one warmup + timed reps (all ranks in lockstep, so
                // per-rank timing equals wall timing)
                for _ in 0..reps + 1 {
                    col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                }
            });
        }
    });
    // subtract nothing for the warmup: it amortizes thread spawn
    t0.elapsed().as_secs_f64() / (reps + 1) as f64
}

fn main() {
    // ---- measured: inproc ring all-reduce ----
    let sizes = [(3_023usize, "lstm"), (32_963, "mlp"),
                 (262_144, "1MB")];
    let worlds = [2usize, 4, 8];
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (floats, tag) in sizes {
        let mut row = vec![format!("{tag} ({floats} f32)")];
        for &n in &worlds {
            let reps = if floats > 100_000 { 30 } else { 100 };
            let t = measure_ring(n, floats, reps);
            // per-rank payload volume of the chunked ring
            let bytes = 2.0 * (n as f64 - 1.0) / n as f64
                * (floats * 4) as f64;
            row.push(format!("{} ({:.2} GB/s)", fmt_secs(t),
                             bytes / t / 1e9));
            csv.push(vec![
                tag.to_string(),
                format!("{floats}"),
                format!("{n}"),
                format!("{t:.3e}"),
            ]);
        }
        rows.push(row);
    }
    print_table(
        "measured inproc ring all-reduce (time + algorithm bandwidth)",
        &["payload", "n=2", "n=4", "n=8"],
        &rows,
    );
    write_csv("runs/bench/allreduce_inproc.csv",
              &["payload", "floats", "ranks", "time_s"], &csv).unwrap();

    // ---- simulated: PS vs ring at paper scale ----
    // paper_gpu: the testbed whose master saturates at ~30x (Fig 4).
    let cost = CostModel::paper_gpu(3_023);
    let base = SimConfig {
        n_workers: 1,
        total_samples: 950_000,
        batch: 100,
        epochs: 10,
        validate_every: 0,
        sync: false,
    };
    let t1 = simulate_async(&cost, &base, 2017).total_time_s;
    let t1_ring = simulate_allreduce(&cost, &base, 2017).total_time_s;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for w in [1usize, 2, 4, 8, 16, 30, 45, 60, 120] {
        let cfg = SimConfig { n_workers: w, ..base.clone() };
        let ps = t1 / simulate_async(&cost, &cfg, 2017 ^ w as u64)
            .total_time_s;
        let ring = t1_ring
            / simulate_allreduce(&cost, &cfg, 2017 ^ w as u64)
                .total_time_s;
        rows.push(vec![
            format!("{w}"),
            format!("{ps:.2}"),
            format!("{ring:.2}"),
            format!("{:.2}", ring / ps),
        ]);
        csv.push(vec![format!("{w}"), format!("{ps:.4}"),
                      format!("{ring:.4}")]);
    }
    print_table(
        "simulated speedup: parameter server vs ring all-reduce \
         (paper-GPU preset, batch 100)",
        &["workers", "PS speedup", "ring speedup", "ring/PS"],
        &rows,
    );
    write_csv("runs/bench/allreduce_vs_ps.csv",
              &["workers", "ps_speedup", "ring_speedup"], &csv).unwrap();
    println!("\nThe PS curve saturates at ~1/t_update gradients/s \
              (Figs 3/4); the ring curve keeps scaling until the \
              latency term 2(n-1)*lat catches up.");
}
