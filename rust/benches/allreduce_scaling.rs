//! Ring all-reduce scaling: measured collective latency on the inproc
//! transport (per wire codec), plus simulated Fig-3/4-style speedup
//! curves comparing the parameter-server protocol against the
//! masterless ring — raw and compressed. The PS master saturates; the
//! ring does not; compression then cuts the ring's bandwidth term.
//!
//!     cargo bench --bench allreduce_scaling
//!     cargo bench --bench allreduce_scaling -- --ci --json out.json

use std::collections::BTreeMap;

use mpi_learn::mpi;
use mpi_learn::mpi::collective::{Collective, ReduceOp};
use mpi_learn::mpi::Codec;
use mpi_learn::simulator::{simulate_allreduce, simulate_async,
                           CostModel, SimConfig};
use mpi_learn::util::bench::{fmt_secs, print_table, write_csv,
                             write_json};
use mpi_learn::util::cli::Args;
use mpi_learn::util::json::Json;

/// Wall time per all-reduce for `n` ranks over `floats` elements.
fn measure_ring(n: usize, floats: usize, reps: usize, codec: Codec)
    -> f64 {
    let world = mpi::inproc_world(n);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for comm in world {
            s.spawn(move || {
                let mut col = Collective::new(&comm);
                col.set_codec(codec);
                let mut buf = vec![1.0f32; floats];
                // one warmup + timed reps (all ranks in lockstep, so
                // per-rank timing equals wall timing)
                for _ in 0..reps + 1 {
                    col.allreduce(&mut buf, ReduceOp::Sum).unwrap();
                }
            });
        }
    });
    // subtract nothing for the warmup: it amortizes thread spawn
    t0.elapsed().as_secs_f64() / (reps + 1) as f64
}

fn main() {
    let args = Args::from_env();
    let ci = args.bool("ci");
    let json_path = args.str("json", "runs/bench/allreduce_scaling.json");
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }

    // ---- measured: inproc ring all-reduce, per codec ----
    let sizes: &[(usize, &str)] = if ci {
        &[(3_023, "lstm"), (32_963, "mlp")]
    } else {
        &[(3_023, "lstm"), (32_963, "mlp"), (262_144, "1MB")]
    };
    let worlds: &[usize] = if ci { &[2, 4] } else { &[2, 4, 8] };
    let codecs = [
        ("fp32", Codec::Fp32),
        ("fp16", Codec::Fp16),
        ("topk10", Codec::TopK { k: 0.1 }),
    ];
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut measured: BTreeMap<String, f64> = BTreeMap::new();
    for &(floats, tag) in sizes {
        for (cname, codec) in codecs {
            let mut row = vec![format!("{tag} ({floats} f32)"),
                               cname.to_string()];
            for &n in worlds {
                let reps = match (ci, floats > 100_000) {
                    (true, _) => 10,
                    (false, true) => 30,
                    (false, false) => 100,
                };
                let t = measure_ring(n, floats, reps, codec);
                // per-rank payload volume of the chunked ring
                let bytes = 2.0 * (n as f64 - 1.0) / n as f64
                    * (floats * 4) as f64 * codec.wire_ratio();
                row.push(format!("{} ({:.2} GB/s)", fmt_secs(t),
                                 bytes / t / 1e9));
                measured.insert(format!("{tag}/{cname}/n{n}"), t);
                csv.push(vec![
                    tag.to_string(),
                    cname.to_string(),
                    format!("{floats}"),
                    format!("{n}"),
                    format!("{t:.3e}"),
                ]);
            }
            rows.push(row);
        }
    }
    let mut header = vec!["payload", "codec"];
    let world_labels: Vec<String> =
        worlds.iter().map(|n| format!("n={n}")).collect();
    header.extend(world_labels.iter().map(|s| s.as_str()));
    print_table(
        "measured inproc ring all-reduce (time + algorithm bandwidth)",
        &header,
        &rows,
    );
    write_csv("runs/bench/allreduce_inproc.csv",
              &["payload", "codec", "floats", "ranks", "time_s"],
              &csv).unwrap();

    // ---- simulated: PS vs ring (raw and fp16) at paper scale ----
    // paper_gpu: the testbed whose master saturates at ~30x (Fig 4).
    let cost = CostModel::paper_gpu(3_023);
    let cost_fp16 = cost.clone().with_compression(Codec::Fp16);
    let base = SimConfig {
        n_workers: 1,
        total_samples: if ci { 95_000 } else { 950_000 },
        batch: 100,
        epochs: if ci { 1 } else { 10 },
        validate_every: 0,
        sync: false,
    };
    let t1 = simulate_async(&cost, &base, 2017).total_time_s;
    let t1_ring = simulate_allreduce(&cost, &base, 2017).total_time_s;
    let t1_ring16 =
        simulate_allreduce(&cost_fp16, &base, 2017).total_time_s;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for w in [1usize, 2, 4, 8, 16, 30, 45, 60, 120] {
        let cfg = SimConfig { n_workers: w, ..base.clone() };
        let seed = 2017 ^ w as u64;
        let ps = t1 / simulate_async(&cost, &cfg, seed).total_time_s;
        let ring = t1_ring
            / simulate_allreduce(&cost, &cfg, seed).total_time_s;
        let ring16 = t1_ring16
            / simulate_allreduce(&cost_fp16, &cfg, seed).total_time_s;
        rows.push(vec![
            format!("{w}"),
            format!("{ps:.2}"),
            format!("{ring:.2}"),
            format!("{ring16:.2}"),
            format!("{:.2}", ring / ps),
        ]);
        csv.push(vec![format!("{w}"), format!("{ps:.4}"),
                      format!("{ring:.4}"), format!("{ring16:.4}")]);
    }
    print_table(
        "simulated speedup: parameter server vs ring all-reduce \
         (paper-GPU preset, batch 100)",
        &["workers", "PS speedup", "ring speedup", "ring+fp16",
          "ring/PS"],
        &rows,
    );
    write_csv("runs/bench/allreduce_vs_ps.csv",
              &["workers", "ps_speedup", "ring_speedup",
                "ring_fp16_speedup"],
              &csv).unwrap();
    println!("\nThe PS curve saturates at ~1/t_update gradients/s \
              (Figs 3/4); the ring curve keeps scaling until the \
              latency term 2(n-1)*lat catches up — compression \
              shrinks only the bandwidth term.");

    let summary: BTreeMap<String, Json> = [
        ("bench".to_string(),
         Json::Str("allreduce_scaling".to_string())),
        ("ci".to_string(), Json::Bool(ci)),
        ("measured_s".to_string(),
         Json::Obj(measured
             .iter()
             .map(|(k, v)| (k.clone(), Json::Num(*v)))
             .collect())),
    ]
    .into_iter()
    .collect();
    write_json(&json_path, &Json::Obj(summary)).unwrap();
    println!("wrote {json_path}");
}
