//! §V overhead claim: "The time needed to train the model with mpi_learn
//! and a single worker process is also compared to the training time
//! obtained using Keras alone. The times are similar, indicating that the
//! training overhead from the mpi_learn framework itself is small."
//!
//! REAL measurement (single worker needs no parallel hardware): identical
//! workload through (a) the full framework — master thread, worker
//! thread, tagged messages, weight round-trips — and (b) the bare compute
//! loop (`train_direct`). Also via the TCP transport for the worst case.
//!
//!     cargo bench --bench overhead_single_worker

use mpi_learn::coordinator::{train, train_direct, Algo, Data,
                             ModelBuilder, TrainConfig, Transport};
use mpi_learn::data::GeneratorConfig;
use mpi_learn::util::bench::{print_table, write_csv};
use mpi_learn::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let samples = args.usize("samples", 3000).unwrap();
    let epochs = args.usize("epochs", 3).unwrap() as u32;
    let reps = args.usize("reps", 3).unwrap();
    args.finish().unwrap();

    let session = match mpi_learn::runtime::Session::open_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP overhead bench: {e}");
            return;
        }
    };
    let data = Data::Synthetic {
        gen: GeneratorConfig::default(),
        samples_per_worker: samples,
        val_samples: 200,
    };
    let cfg = TrainConfig {
        builder: ModelBuilder::new("lstm", 100),
        algo: Algo {
            batch_size: 100,
            epochs,
            validate_every: 0,
            max_val_batches: 1,
            ..Algo::default()
        },
        n_workers: 1,
        seed: 3,
        transport: Transport::Inproc,
        hierarchy: None,
        callbacks: Vec::new(),
    };

    let mut t_direct = Vec::new();
    let mut t_inproc = Vec::new();
    let mut t_tcp = Vec::new();
    for rep in 0..reps {
        t_direct.push(train_direct(&session, &cfg, &data).unwrap()
            .wallclock_s);
        t_inproc.push(train(&session, &cfg, &data).unwrap().wallclock_s);
        let tcp_cfg = TrainConfig {
            transport: Transport::Tcp { base_port: 48400
                + rep as u16 * 4 },
            ..cfg.clone()
        };
        t_tcp.push(train(&session, &tcp_cfg, &data).unwrap().wallclock_s);
    }
    let med = |v: &[f64]| mpi_learn::util::stats::percentile(v, 50.0);
    let (d, i, t) = (med(&t_direct), med(&t_inproc), med(&t_tcp));

    let rows = vec![
        vec!["direct loop (\"Keras alone\")".into(), format!("{d:.3}"),
             "1.000".into()],
        vec!["mpi-learn, 1 worker, inproc".into(), format!("{i:.3}"),
             format!("{:.3}", i / d)],
        vec!["mpi-learn, 1 worker, tcp".into(), format!("{t:.3}"),
             format!("{:.3}", t / d)],
    ];
    print_table(
        &format!("framework overhead — {samples} samples x {epochs} \
                  epochs, batch 100 (median of {reps})"),
        &["configuration", "wallclock s", "ratio vs direct"],
        &rows,
    );
    write_csv("runs/bench/overhead_single_worker.csv",
              &["config", "seconds"],
              &[vec!["direct".into(), format!("{d:.4}")],
                vec!["inproc".into(), format!("{i:.4}")],
                vec!["tcp".into(), format!("{t:.4}")]]).unwrap();
    println!("\npaper: \"the times are similar\" — target ratio ≲ 1.05 \
              for inproc.");
}
