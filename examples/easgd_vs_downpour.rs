//! Compare the paper's two distributed algorithms on the same workload:
//! asynchronous Downpour SGD vs Elastic Averaging SGD at several exchange
//! periods tau (§III-A) — each variant one `Experiment` chain.
//!
//!     cargo run --release --example easgd_vs_downpour

use mpi_learn::coordinator::{Algo, Data, Experiment, Mode};
use mpi_learn::data::GeneratorConfig;
use mpi_learn::optim::OptimizerConfig;
use mpi_learn::util::bench::print_table;
use mpi_learn::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let workers = args.usize("workers", 4)?;
    let epochs = args.usize("epochs", 4)? as u32;
    args.finish()?;

    let session = mpi_learn::runtime::Session::open_default()?;
    let data = Data::Synthetic {
        gen: GeneratorConfig { separation: 0.12, noise: 2.0,
                               ..Default::default() },
        samples_per_worker: 1500,
        val_samples: 1500,
    };

    let base = Algo {
        batch_size: 100,
        epochs,
        validate_every: 0, // only final validation -> fair wallclock
        max_val_batches: 10,
        ..Algo::default()
    };

    let variants: Vec<(String, Algo)> = vec![
        ("downpour-async".into(), base.clone()),
        ("downpour-sync".into(),
         Algo { mode: Mode::Downpour { sync: true }, ..base.clone() }),
        ("easgd tau=2".into(), easgd(&base, 2)),
        ("easgd tau=8".into(), easgd(&base, 8)),
        ("easgd tau=32".into(), easgd(&base, 32)),
    ];

    let mut rows = Vec::new();
    for (name, algo) in variants {
        let r = Experiment::new("lstm")
            .batch(algo.batch_size)
            .workers(workers)
            .algo(algo)
            .data(data.clone())
            .run(&session)?;
        let v = r.history.validations.last().cloned().unwrap();
        rows.push(vec![
            name,
            format!("{:.2}", r.wallclock_s),
            format!("{}", r.history.master_updates),
            format!("{:.4}", v.val_loss),
            format!("{:.4}", v.val_acc),
        ]);
    }
    print_table(
        &format!("Downpour vs EASGD — {workers} workers, {epochs} epochs"),
        &["algorithm", "wall_s", "master_updates", "val_loss", "val_acc"],
        &rows,
    );
    println!("\nNote: EASGD exchanges weights only every tau batches, so \
              master traffic\nfalls as tau grows; workers explore \
              independently between pulls (§III-A).");
    Ok(())
}

fn easgd(base: &Algo, tau: u32) -> Algo {
    Algo {
        mode: Mode::Easgd {
            tau,
            alpha: 0.5,
            worker_optimizer: OptimizerConfig::Momentum {
                lr: 0.05, momentum: 0.9, nesterov: false },
        },
        ..base.clone()
    }
}
