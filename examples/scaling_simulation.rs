//! Cluster-scale what-if explorer: the calibrated protocol simulator as a
//! user tool. Measures this host's real per-batch gradient cost and
//! master update cost, then projects speedup curves for arbitrary worker
//! counts, batch sizes, and validation cadences on the paper's two
//! testbed presets. (This one projects instead of training — for real
//! runs use the `Experiment` facade, see `examples/quickstart.rs`.)
//!
//!     cargo run --release --example scaling_simulation
//!     cargo run --release --example scaling_simulation -- \
//!         --workers 1,4,16,64,256 --preset shared

use std::time::Instant;

use mpi_learn::simulator::{speedup_curve, CostModel, SimConfig};
use mpi_learn::tensor::ParamSet;
use mpi_learn::util::bench::print_table;
use mpi_learn::util::cli::Args;
use mpi_learn::util::rng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let worker_counts =
        args.usize_list("workers", &[1, 2, 4, 8, 16, 30, 45, 60])?;
    let preset = args.str("preset", "cluster");
    let batch = args.usize("batch", 100)?;
    args.finish()?;

    // --- calibration: measure the real runtime ---
    let session = mpi_learn::runtime::Session::open_default()?;
    let exes = session.executables_for("lstm", batch)?;
    let meta = &exes.meta;
    let mut rng = Rng::new(0);
    let params = exes.init_params(&mut rng);
    let x: Vec<f32> = (0..meta.x_len()).map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let y: Vec<i32> = (0..meta.batch).map(|_| rng.usize_below(3) as i32)
        .collect();
    exes.grad_step(&params, &x, &y)?; // warm
    let t0 = Instant::now();
    let reps = 20;
    for _ in 0..reps {
        exes.grad_step(&params, &x, &y)?;
    }
    let t_grad = t0.elapsed().as_secs_f64() / reps as f64;

    let mut opt = mpi_learn::optim::OptimizerConfig::default_momentum()
        .build(meta.param_count);
    let mut w = ParamSet::zeros(&meta.params);
    let g = vec![1e-3f32; meta.param_count];
    let t0 = Instant::now();
    for _ in 0..1000 {
        opt.update(w.flat_mut(), &g);
    }
    let t_update = t0.elapsed().as_secs_f64() / 1000.0;

    println!("calibrated on this host: t_grad(batch {})={:.2}ms, \
              t_update={:.1}us, {} params",
             batch, t_grad * 1e3, t_update * 1e6, meta.param_count);

    let mut cost = match preset.as_str() {
        "shared" => CostModel::shared_memory(meta.param_count),
        _ => CostModel::cluster(meta.param_count),
    };
    cost.t_grad_fixed = 0.0;
    cost.t_grad_per_sample = t_grad / batch as f64;
    cost.t_update = t_update;

    let base = SimConfig {
        n_workers: 1,
        total_samples: 950_000, // paper: 100 files x 9500
        batch,
        epochs: 10,
        validate_every: 0,
        sync: false,
    };

    let curve = speedup_curve(&cost, &base, &worker_counts, 2017);
    let rows: Vec<Vec<String>> = curve
        .iter()
        .map(|(w, s)| {
            vec![format!("{w}"), format!("{s:.2}"),
                 format!("{:.1}%", 100.0 * s / *w as f64)]
        })
        .collect();
    print_table(
        &format!("projected speedup — preset '{preset}', batch {batch}, \
                  paper-sized dataset"),
        &["workers", "speedup", "efficiency"],
        &rows,
    );
    Ok(())
}
