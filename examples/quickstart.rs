//! Quickstart: the framework's one-call user API — pick a model, chain
//! the training procedure and the usual Keras-style conveniences onto
//! an [`Experiment`], and `run`.
//!
//!     cargo run --release --example quickstart
//!     cargo run --release --example quickstart -- --model transformer \
//!         --batch 16 --workers 2 --epochs 1
//!     cargo run --release --example quickstart -- --direct   # no framework
//!     cargo run --release --example quickstart -- --mode allreduce \
//!         --workers 4                       # masterless ring all-reduce
//!     cargo run --release --example quickstart -- --mode hier-allreduce \
//!         --workers 4 --groups 2            # grouped ring + leader tree
//!     cargo run --release --example quickstart -- --mode allreduce \
//!         --compression fp16                # compressed wire hops
//!     cargo run --release --example quickstart -- --mode allreduce \
//!         --buckets         # per-layer all-reduce overlapped w/ backprop
//!     cargo run --release --example quickstart -- --mode allreduce \
//!         --auto            # self-tuning planner picks the topology
//!     cargo run --release --example quickstart -- --mode sync --tcp
//!         # synchronous Downpour over the localhost TCP mesh
//!     cargo run --release --example quickstart -- --early-stopping 3 \
//!         --checkpoint runs/quickstart      # callbacks
//!
//! The CI mode-matrix job runs this example across every
//! mode × transport × codec cell, so each flag combination here is a
//! supported, smoke-tested configuration.

use mpi_learn::coordinator::Experiment;
use mpi_learn::mpi::Codec;
use mpi_learn::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let model = args.str("model", "mlp");
    let batch = args.usize("batch", 100)?;
    let workers = args.usize("workers", 2)?;
    let epochs = args.usize("epochs", 3)? as u32;
    let direct = args.bool("direct");
    // --allreduce is the historical spelling of --mode allreduce
    let allreduce_flag = args.bool("allreduce");
    let mode = args.str("mode",
                        if allreduce_flag { "allreduce" }
                        else { "downpour" });
    let groups = args.usize("groups", 2)?;
    let tcp = args.bool("tcp");
    let compression = Codec::parse(&args.str("compression", "fp32"))?;
    let buckets = args.bool("buckets");
    let auto = args.bool("auto");
    let patience = args.usize("early-stopping", 0)?;
    let checkpoint = args.str_opt("checkpoint");
    args.finish()?;

    // 1. a session: AOT artifacts if present, else the built-in
    //    zero-setup native CPU backend
    let session = mpi_learn::runtime::Session::open_default()?;

    // 2. the experiment: model + data + training procedure + callbacks
    //    in one chain (synthetic HEP-like benchmark data by default)
    let mut exp = Experiment::new(&model)
        .batch(batch)
        .workers(workers)
        .epochs(epochs)
        .validate_every(20)
        .max_val_batches(5);
    if direct {
        println!("running the no-framework baseline (\"Keras alone\")...");
        exp = exp.direct();
    } else {
        exp = match mode.as_str() {
            "downpour" => {
                println!("running async Downpour with {workers} \
                          workers...");
                exp.downpour()
            }
            "sync" => {
                println!("running synchronous Downpour with {workers} \
                          workers...");
                exp.downpour_sync()
            }
            "easgd" => {
                println!("running EASGD with {workers} workers...");
                exp.easgd(4, 0.5)
            }
            "allreduce" => {
                println!("running masterless ring all-reduce with \
                          {workers} ranks...");
                exp.allreduce()
            }
            "hier-allreduce" => {
                println!("running hierarchical all-reduce with \
                          {workers} ranks in {groups} groups...");
                exp.allreduce_grouped(groups)
            }
            other => return Err(format!(
                "unknown --mode '{other}' (downpour | sync | easgd | \
                 allreduce | hier-allreduce)")
                .into()),
        };
    }
    if tcp {
        println!("carrying the protocol over a localhost TCP mesh...");
        exp = exp.tcp(47810);
    }
    if !compression.is_identity() {
        println!("compressing gradient exchange with {compression}...");
        exp = exp.compression(compression);
    }
    if buckets {
        println!("bucketing the all-reduce per layer, overlapped with \
                  backprop...");
        exp = exp.buckets();
    }
    if auto {
        println!("self-tuning the topology: probing links, sweeping the \
                  cost model...");
        exp = exp.auto_tune();
    }
    if patience > 0 {
        exp = exp.early_stopping(patience as u32);
    }
    if let Some(dir) = checkpoint {
        exp = exp.checkpoint(dir);
    }

    // 3. run
    let result = exp.run(&session)?;

    let h = &result.history;
    println!("\n{:>8} {:>10} {:>10}", "update", "val_loss", "val_acc");
    for v in &h.validations {
        println!("{:>8} {:>10.4} {:>10.4}", v.update, v.val_loss,
                 v.val_acc);
    }
    println!(
        "\ndone in {:.2}s — {} master updates, {:.0} samples/s, \
         final acc {:.3}",
        result.wallclock_s,
        h.master_updates,
        h.throughput_samples_per_s(),
        h.final_val_acc().unwrap_or(f32::NAN),
    );
    Ok(())
}
