//! Quickstart: the paper's three-class user API in ~30 lines of client
//! code — pick a model (`ModelBuilder`), a training procedure (`Algo`),
//! and a data source (`Data`), then `train`.
//!
//!     cargo run --release --example quickstart
//!     cargo run --release --example quickstart -- --model transformer \
//!         --batch 16 --workers 2 --epochs 1
//!     cargo run --release --example quickstart -- --direct   # no framework
//!     cargo run --release --example quickstart -- --allreduce \
//!         --workers 4                       # masterless ring all-reduce

use mpi_learn::coordinator::{train, train_direct, Algo, Data, Mode,
                             ModelBuilder, TrainConfig, Transport};
use mpi_learn::data::GeneratorConfig;
use mpi_learn::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let model = args.str("model", "mlp");
    let batch = args.usize("batch", 100)?;
    let workers = args.usize("workers", 2)?;
    let epochs = args.usize("epochs", 3)? as u32;
    let direct = args.bool("direct");
    let allreduce = args.bool("allreduce");
    args.finish()?;

    // 1. the model: an artifact variant (AOT-compiled, or the built-in
    //    native backend when no artifacts are present)
    let builder = ModelBuilder::new(&model, batch);

    // 2. the training procedure: async Downpour with momentum SGD, or
    //    the masterless synchronous ring all-reduce
    let algo = Algo {
        mode: if allreduce { Mode::AllReduce }
              else { Algo::default().mode },
        batch_size: batch,
        epochs,
        validate_every: 20,
        max_val_batches: 5,
        ..Algo::default()
    };

    // 3. the data: synthetic HEP-like benchmark task
    let data = Data::Synthetic {
        gen: GeneratorConfig::default(),
        samples_per_worker: 2000,
        val_samples: 1000,
    };

    let session = mpi_learn::runtime::Session::open_default()?;
    let cfg = TrainConfig {
        builder,
        algo,
        n_workers: workers,
        seed: 2017,
        transport: Transport::Inproc,
        hierarchy: None,
    };

    let result = if direct {
        println!("running the no-framework baseline (\"Keras alone\")...");
        train_direct(&session, &cfg, &data)?
    } else {
        if allreduce {
            println!("running masterless ring all-reduce with {workers} \
                      ranks...");
        } else {
            println!("running async Downpour with {workers} workers...");
        }
        train(&session, &cfg, &data)?
    };

    let h = &result.history;
    println!("\n{:>8} {:>10} {:>10}", "update", "val_loss", "val_acc");
    for v in &h.validations {
        println!("{:>8} {:>10.4} {:>10.4}", v.update, v.val_loss,
                 v.val_acc);
    }
    println!(
        "\ndone in {:.2}s — {} master updates, {:.0} samples/s, \
         final acc {:.3}",
        result.wallclock_s,
        h.master_updates,
        h.throughput_samples_per_s(),
        h.final_val_acc().unwrap_or(f32::NAN),
    );
    Ok(())
}
