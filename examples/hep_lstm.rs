//! End-to-end driver: the paper's benchmark experiment, full pipeline.
//!
//! Reproduces §IV on this host's scale: generate the file-sharded
//! synthetic HEP dataset (the Delphes substitute — N shard files divided
//! evenly among workers, exactly the paper's `Data` flow), train the
//! LSTM(20)+softmax(3) with asynchronous Downpour SGD + momentum for the
//! configured epochs — with the full callback stack attached: best-val
//! checkpointing, early stopping, and streaming JSONL metrics — then
//! dump the loss/accuracy curves as CSV for EXPERIMENTS.md.
//!
//!     cargo run --release --example hep_lstm
//!     cargo run --release --example hep_lstm -- --files 32 \
//!         --samples 4000 --workers 8 --epochs 10

use std::path::PathBuf;

use mpi_learn::coordinator::{Data, Experiment};
use mpi_learn::data::{generate_dataset, GeneratorConfig};
use mpi_learn::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    // paper: 100 files x 9500 samples; default here is a 20x-scaled-down
    // replica that trains in minutes on one CPU core
    let files = args.usize("files", 20)?;
    let samples = args.usize("samples", 1000)?;
    let workers = args.usize("workers", 4)?;
    let epochs = args.usize("epochs", 10)? as u32;
    let batch = args.usize("batch", 100)?;
    let patience = args.usize("early-stopping", 0)?;
    let out_dir = PathBuf::from(args.str("out", "runs/hep_lstm"));
    args.finish()?;

    let data_dir = out_dir.join("data");
    println!("[1/3] generating {files} shard files x {samples} samples \
              (+ validation shard) in {}", data_dir.display());
    let gen = GeneratorConfig {
        separation: 0.10, // hard task: accuracy plateaus below 100%
        noise: 2.2,
        ..Default::default()
    };
    let (train_files, val_file) =
        generate_dataset(&gen, &data_dir, files, samples, 2000)?;

    println!("[2/3] training lstm_b{batch} with {workers} async Downpour \
              workers for {epochs} epochs");
    let session = mpi_learn::runtime::Session::open_default()?;
    let mut exp = Experiment::new("lstm")
        .batch(batch)
        .workers(workers)
        .epochs(epochs)
        .validate_every(25)
        .max_val_batches(10)
        .data(Data::Files { train: train_files, val: val_file })
        .checkpoint(out_dir.join("ckpt"))
        .jsonl_log(out_dir.join("metrics.jsonl"));
    if patience > 0 {
        exp = exp.early_stopping(patience as u32);
    }
    let result = exp.run(&session)?;
    let h = &result.history;

    println!("[3/3] writing curves to {}", out_dir.display());
    std::fs::write(out_dir.join("validation.csv"),
                   h.validations_csv())?;
    std::fs::write(out_dir.join("train_loss.csv"), h.train_loss_csv())?;
    result.weights.save(&out_dir.join("weights.ckpt"))?;

    println!("\n== loss curve (train, sampled every 16 updates) ==");
    for (u, l) in h.train_losses.iter().step_by(
        (h.train_losses.len() / 12).max(1)) {
        println!("  update {u:>6}: loss {l:.4}");
    }
    println!("\n== validation curve ==");
    for v in &h.validations {
        println!("  t={:>7.2}s update={:>6} loss={:.4} acc={:.4}",
                 v.t_s, v.update, v.val_loss, v.val_acc);
    }
    println!("\n== summary ==");
    println!("  wallclock            {:.2}s", result.wallclock_s);
    println!("  master updates       {}", h.master_updates);
    println!("  master update time   {:.2}s", h.master_update_time_s);
    println!("  master idle time     {:.2}s", h.master_idle_time_s);
    println!("  throughput           {:.0} samples/s",
             h.throughput_samples_per_s());
    println!("  final validation acc {:.4}",
             h.final_val_acc().unwrap_or(f32::NAN));
    println!("  best val loss        {:.4} (checkpointed to {})",
             h.best_val_loss().unwrap_or(f32::NAN),
             out_dir.join("ckpt/best.mplw").display());
    for w in &h.workers {
        println!(
            "  worker {:>2}: {} batches, grad {:.2}s, comm-wait {:.2}s",
            w.rank, w.batches, w.grad_time_s, w.comm_wait_s);
    }
    Ok(())
}
