//! Hierarchical masters (§III-A): several group masters, each serving a
//! worker pool and reporting to a super-master. Compares flat 1-master
//! topology vs 2 and 4 groups on identical data — one `Experiment`
//! chain per topology (`.hierarchy(groups, workers_per_group,
//! sync_every)` is the only difference).
//!
//!     cargo run --release --example hierarchical

use mpi_learn::coordinator::{Data, Experiment};
use mpi_learn::data::GeneratorConfig;
use mpi_learn::util::bench::print_table;
use mpi_learn::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let epochs = args.usize("epochs", 3)? as u32;
    args.finish()?;

    let session = mpi_learn::runtime::Session::open_default()?;
    let data = Data::Synthetic {
        gen: GeneratorConfig { separation: 0.12, noise: 2.0,
                               ..Default::default() },
        samples_per_worker: 1000,
        val_samples: 1000,
    };

    // all topologies train 4 workers on the same divided dataset:
    // (name, Some((groups, workers_per_group, sync_every)))
    let topologies: Vec<(String, Option<(usize, usize, u64)>)> = vec![
        ("flat: 1 master x 4 workers".into(), None),
        ("2 groups x 2 workers, sync_every=5".into(), Some((2, 2, 5))),
        ("4 groups x 1 worker, sync_every=5".into(), Some((4, 1, 5))),
    ];

    let mut rows = Vec::new();
    for (name, hierarchy) in topologies {
        let mut exp = Experiment::new("lstm")
            .batch(100)
            .workers(4)
            .epochs(epochs)
            .max_val_batches(10)
            .data(data.clone());
        if let Some((groups, wpg, sync_every)) = hierarchy {
            exp = exp.hierarchy(groups, wpg, sync_every);
        }
        let r = exp.run(&session)?;
        let v = r.history.validations.last().cloned().unwrap();
        rows.push(vec![
            name,
            format!("{:.2}", r.wallclock_s),
            format!("{}", r.history.master_updates),
            format!("{:.4}", v.val_acc),
        ]);
    }
    print_table(
        "Flat vs hierarchical topology — 4 workers",
        &["topology", "wall_s", "top-master updates", "val_acc"],
        &rows,
    );
    println!("\nIn the hierarchical runs the top master only sees one \
              aggregated delta\nper group sync, so its update count \
              drops by ~sync_every x group size —\nthe mechanism that \
              relieves the single-master bottleneck at cluster scale.");
    Ok(())
}
